//! Batch-and-drain compat surface over the streaming [`Engine`].
//!
//! The worker threads, per-worker KV pools, and least-loaded routing that
//! used to live here moved into [`super::engine`]; what remains is the thin
//! submit-all/drain-all wrapper ([`serve_requests`]) that offline callers
//! (benches, tables, the pipeline demo) still want, plus the synthetic
//! request-trace builder. New code should use [`Engine::submit`] directly
//! and consume the token stream.

use super::batcher::{BatchMetrics, FinishReason, GenRequest};
use super::engine::{Engine, EngineConfig, RequestHandle, Response};
use crate::model::Gpt;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Engine sizing under its pre-streaming name: the compat wrapper takes the
/// same configuration the `Engine` does.
pub type ServerConfig = EngineConfig;

/// Aggregated server outcome of one batch-and-drain run.
pub struct ServerRun {
    pub responses: Vec<Response>,
    pub per_worker: Vec<BatchMetrics>,
    pub wall: std::time::Duration,
}

impl ServerRun {
    pub fn throughput_tok_s(&self) -> f64 {
        let toks: usize = self.per_worker.iter().map(|m| m.generated_tokens).sum();
        toks as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Prompt tokens absorbed per wall second across all workers — the
    /// chunked-prefill throughput the long-prompt TTFT benches track.
    pub fn prefill_tok_s(&self) -> f64 {
        let toks: usize = self.per_worker.iter().map(|m| m.prefill_tokens).sum();
        toks as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Admissions that adopted ≥ 1 cached prefix page, across all workers.
    pub fn prefix_hits(&self) -> usize {
        self.per_worker.iter().map(|m| m.prefix_hits).sum()
    }

    /// Prompt tokens served from cached prefix pages instead of prefill,
    /// across all workers.
    pub fn prefix_hit_tokens(&self) -> usize {
        self.per_worker.iter().map(|m| m.prefix_hit_tokens).sum()
    }

    /// Fraction of all prompt tokens served from the prefix cache
    /// (`hit / (hit + prefilled)`); 0.0 when no prompts ran.
    pub fn prefix_hit_rate(&self) -> f64 {
        let hit: usize = self.prefix_hit_tokens();
        let cold: usize = self.per_worker.iter().map(|m| m.prefill_tokens).sum();
        if hit + cold == 0 {
            return 0.0;
        }
        hit as f64 / (hit + cold) as f64
    }

    /// Highest per-worker pool-occupancy high-water mark (leased +
    /// trie-cached tokens) — the KV pressure headline for summaries.
    pub fn peak_kv_tokens(&self) -> usize {
        self.per_worker.iter().map(|m| m.peak_tokens).max().unwrap_or(0)
    }

    /// Latency samples over **completed** requests only
    /// ([`super::batcher::FinishReason::is_completed`]): rejected requests
    /// never ran and
    /// cancelled requests were cut short, so neither carries a full latency
    /// signal — including them would skew the percentiles low.
    fn completed_ms(&self, f: impl Fn(&Response) -> f64) -> Vec<f64> {
        let mut ms: Vec<f64> =
            self.responses.iter().filter(|r| r.finish.is_completed()).map(f).collect();
        ms.sort_by(f64::total_cmp);
        ms
    }

    /// End-to-end latency percentile (ms) over completed requests only (see
    /// [`ServerRun::completed_ms`]).
    pub fn latency_percentile_ms(&self, p: f64) -> f64 {
        let ms = self.completed_ms(|r| r.total.as_secs_f64() * 1e3);
        // 0.0, not NaN, when every request was rejected: NaN would serialize
        // as invalid JSON in BENCH_serving.json.
        if ms.is_empty() {
            return 0.0;
        }
        crate::util::stats::percentile_sorted(&ms, p)
    }

    /// TTFT percentile (ms) over completed requests only (see
    /// [`ServerRun::completed_ms`]).
    pub fn ttft_percentile_ms(&self, p: f64) -> f64 {
        let ms = self.completed_ms(|r| r.ttft.as_secs_f64() * 1e3);
        if ms.is_empty() {
            return 0.0;
        }
        crate::util::stats::percentile_sorted(&ms, p)
    }
}

/// Submit every request to a fresh [`Engine`], wait for every stream to
/// finish, and aggregate the responses — the pre-streaming blocking surface,
/// now a thin wrapper. Greedy requests reproduce the pre-redesign outputs
/// token-for-token (property-tested in `rust/tests/properties.rs`).
pub fn serve_requests(
    model: Arc<Gpt>,
    cfg: &ServerConfig,
    requests: Vec<GenRequest>,
) -> ServerRun {
    let t0 = Instant::now();
    let engine = Engine::new(model, cfg.clone());
    // `ServerConfig` may bound the per-worker submit queues (`queue_cap`).
    // A blocking batch surface waits out transient pressure rather than
    // shedding; a request that still cannot be admitted — or that raced a
    // shutdown — is reported as `Rejected` instead of panicking the caller.
    let mut responses: Vec<Response> = Vec::new();
    let handles: Vec<RequestHandle> = requests
        .into_iter()
        .filter_map(|req| match engine.submit_wait(req, Duration::from_secs(60)) {
            Ok(h) => Some(h),
            Err(e) => {
                let req = e.into_request();
                let waited = req.submitted.elapsed();
                responses.push(Response {
                    id: req.id,
                    tokens: Vec::new(),
                    ttft: waited,
                    total: waited,
                    prompt_len: req.prompt.len(),
                    finish: FinishReason::Rejected,
                });
                None
            }
        })
        .collect();
    responses.extend(handles.into_iter().map(|h| h.wait()));
    let per_worker = engine.shutdown();
    ServerRun { responses, per_worker, wall: t0.elapsed() }
}

/// Build a standard greedy request batch from corpus prompts (demo +
/// benches). Per-request sampling can be overridden on the returned
/// requests before submission.
pub fn synthetic_requests(
    vocab_size: usize,
    n: usize,
    prompt_len: usize,
    max_new: usize,
    seed: u64,
) -> anyhow::Result<Vec<GenRequest>> {
    let corpus = crate::data::corpus(vocab_size, "wiki")?;
    let mut rng = crate::util::rng::Pcg64::new(seed, 0x5e12e);
    Ok((0..n)
        .map(|i| GenRequest::new(i as u64, corpus.stream(&mut rng, prompt_len), max_new))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FinishReason;
    use crate::model::synthetic_model;

    #[test]
    fn multi_worker_serves_everything() {
        let model = Arc::new(synthetic_model("micro", 61).unwrap());
        let reqs = synthetic_requests(model.cfg.vocab_size, 12, 4, 3, 9).unwrap();
        let cfg = ServerConfig { workers: 3, kv_tokens: 4096, ..Default::default() };
        let run = serve_requests(model, &cfg, reqs);
        assert_eq!(run.responses.len(), 12);
        assert_eq!(run.per_worker.len(), 3);
        let total: usize = run.per_worker.iter().map(|m| m.requests).sum();
        assert_eq!(total, 12);
        assert!(run.responses.iter().all(|r| r.finish.is_completed()));
        assert!(run.throughput_tok_s() > 0.0);
        assert!(run.prefill_tok_s() > 0.0);
        assert!(run.latency_percentile_ms(50.0) >= run.ttft_percentile_ms(50.0) * 0.5);
    }

    #[test]
    fn routing_spreads_load() {
        let model = Arc::new(synthetic_model("micro", 62).unwrap());
        let reqs = synthetic_requests(model.cfg.vocab_size, 16, 4, 2, 10).unwrap();
        let cfg = ServerConfig { workers: 4, kv_tokens: 4096, ..Default::default() };
        let run = serve_requests(model, &cfg, reqs);
        // Every worker should have taken some share under least-loaded.
        let busy = run.per_worker.iter().filter(|m| m.requests > 0).count();
        assert!(busy >= 3, "only {busy} workers used");
    }

    #[test]
    fn single_worker_equals_batcher_semantics() {
        let model = Arc::new(synthetic_model("micro", 63).unwrap());
        let prompt = vec![3u32, 5, 7];
        let want = model.generate_greedy(&prompt, 4);
        let reqs = vec![GenRequest::new(0, prompt, 4)];
        let cfg = ServerConfig { workers: 1, kv_tokens: 4096, ..Default::default() };
        let run = serve_requests(model, &cfg, reqs);
        assert!(want.starts_with(&run.responses[0].tokens) || run.responses[0].tokens == want);
    }

    #[test]
    fn percentiles_skip_non_completed_responses() {
        // One served + one impossible request: the rejected response must
        // not drag the latency percentiles toward its near-zero turnaround.
        let model = Arc::new(synthetic_model("micro", 64).unwrap());
        let long: Vec<u32> = (0..70).map(|i| 1 + (i % 100) as u32).collect();
        let reqs = vec![GenRequest::new(0, vec![2, 3], 3), GenRequest::new(1, long, 3)];
        let cfg = ServerConfig { workers: 1, kv_tokens: 4096, ..Default::default() };
        let run = serve_requests(model, &cfg, reqs);
        assert_eq!(run.responses.len(), 2);
        let served = run.responses.iter().find(|r| r.id == 0).unwrap();
        let rejected = run.responses.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(rejected.finish, FinishReason::Rejected);
        let served_ms = served.total.as_secs_f64() * 1e3;
        assert!((run.latency_percentile_ms(50.0) - served_ms).abs() < 1e-6);
        assert!((run.latency_percentile_ms(5.0) - served_ms).abs() < 1e-6);
    }
}
