//! Request router + multi-worker server.
//!
//! vLLM-router-style front end: N worker replicas (threads), each running
//! the continuous batcher over a shared model snapshot (`Arc<Gpt>` —
//! weights are immutable at serve time). The router assigns each incoming
//! request to the worker with the least outstanding work and aggregates
//! responses + metrics.

use super::batcher::{run_batcher, BatchConfig, BatchMetrics, Request, Response};
use super::kvpool::KvPool;
use crate::model::Gpt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Instant;

pub struct ServerConfig {
    pub workers: usize,
    pub batch: BatchConfig,
    /// KV token budget per worker.
    pub kv_tokens: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { workers: 2, batch: BatchConfig::default(), kv_tokens: 1 << 16 }
    }
}

/// Aggregated server outcome.
pub struct ServerRun {
    pub responses: Vec<Response>,
    pub per_worker: Vec<BatchMetrics>,
    pub wall: std::time::Duration,
}

impl ServerRun {
    pub fn throughput_tok_s(&self) -> f64 {
        let toks: usize = self.per_worker.iter().map(|m| m.generated_tokens).sum();
        toks as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Prompt tokens absorbed per wall second across all workers — the
    /// chunked-prefill throughput the long-prompt TTFT benches track.
    pub fn prefill_tok_s(&self) -> f64 {
        let toks: usize = self.per_worker.iter().map(|m| m.prefill_tokens).sum();
        toks as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Responses that were actually served (admission-rejected requests are
    /// in `responses` for completeness but carry no latency signal, so the
    /// percentile accessors exclude them).
    fn served_ms(&self, f: impl Fn(&Response) -> f64) -> Vec<f64> {
        let mut ms: Vec<f64> = self.responses.iter().filter(|r| !r.rejected).map(f).collect();
        ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ms
    }

    pub fn latency_percentile_ms(&self, p: f64) -> f64 {
        let ms = self.served_ms(|r| r.total.as_secs_f64() * 1e3);
        // 0.0, not NaN, when every request was rejected: NaN would serialize
        // as invalid JSON in BENCH_serving.json.
        if ms.is_empty() {
            return 0.0;
        }
        crate::util::stats::percentile_sorted(&ms, p)
    }

    pub fn ttft_percentile_ms(&self, p: f64) -> f64 {
        let ms = self.served_ms(|r| r.ttft.as_secs_f64() * 1e3);
        if ms.is_empty() {
            return 0.0;
        }
        crate::util::stats::percentile_sorted(&ms, p)
    }
}

struct Worker {
    tx: Sender<Request>,
    load: Arc<AtomicUsize>,
    handle: thread::JoinHandle<BatchMetrics>,
}

/// Route `requests` across workers (least-outstanding-tokens policy), run to
/// completion, and return all responses.
pub fn serve_requests(
    model: Arc<Gpt>,
    cfg: &ServerConfig,
    requests: Vec<Request>,
) -> ServerRun {
    let t0 = Instant::now();
    let responses = Arc::new(Mutex::new(Vec::new()));
    let mut workers: Vec<Worker> = Vec::with_capacity(cfg.workers);
    for _ in 0..cfg.workers.max(1) {
        let (tx, rx) = channel::<Request>();
        let model = Arc::clone(&model);
        let pool = KvPool::for_model(&model.cfg, cfg.kv_tokens * model.cfg.d_model * 8);
        let pool = KvPool::new(cfg.kv_tokens, pool.bytes_per_token);
        let bcfg = cfg.batch.clone();
        let load = Arc::new(AtomicUsize::new(0));
        let load2 = Arc::clone(&load);
        let responses2 = Arc::clone(&responses);
        let handle = thread::spawn(move || {
            run_batcher(&model, &pool, &bcfg, rx, |r: Response| {
                load2.fetch_sub(r.prompt_len + r.tokens.len(), Ordering::SeqCst);
                responses2.lock().unwrap().push(r);
            })
        });
        workers.push(Worker { tx, load, handle });
    }

    // Least-loaded routing by outstanding token estimate.
    for req in requests {
        let cost = req.prompt.len() + req.max_new;
        let w = workers
            .iter()
            .min_by_key(|w| w.load.load(Ordering::SeqCst))
            .expect("workers non-empty");
        w.load.fetch_add(cost, Ordering::SeqCst);
        w.tx.send(req).expect("worker alive");
    }
    // Close queues; workers drain and exit.
    let mut per_worker = Vec::new();
    for w in workers {
        drop(w.tx);
        per_worker.push(w.handle.join().expect("worker panicked"));
    }
    let responses = Arc::try_unwrap(responses).unwrap().into_inner().unwrap();
    ServerRun { responses, per_worker, wall: t0.elapsed() }
}

/// Build a standard request batch from corpus prompts (demo + benches).
pub fn synthetic_requests(
    vocab_size: usize,
    n: usize,
    prompt_len: usize,
    max_new: usize,
    seed: u64,
) -> anyhow::Result<Vec<Request>> {
    let corpus = crate::data::corpus(vocab_size, "wiki")?;
    let mut rng = crate::util::rng::Pcg64::new(seed, 0x5e12e);
    let now = Instant::now();
    Ok((0..n)
        .map(|i| Request {
            id: i as u64,
            prompt: corpus.stream(&mut rng, prompt_len),
            max_new,
            submitted: now,
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_model;

    #[test]
    fn multi_worker_serves_everything() {
        let model = Arc::new(synthetic_model("micro", 61).unwrap());
        let reqs = synthetic_requests(model.cfg.vocab_size, 12, 4, 3, 9).unwrap();
        let cfg = ServerConfig { workers: 3, kv_tokens: 4096, ..Default::default() };
        let run = serve_requests(model, &cfg, reqs);
        assert_eq!(run.responses.len(), 12);
        assert_eq!(run.per_worker.len(), 3);
        let total: usize = run.per_worker.iter().map(|m| m.requests).sum();
        assert_eq!(total, 12);
        assert!(run.throughput_tok_s() > 0.0);
        assert!(run.prefill_tok_s() > 0.0);
        assert!(run.latency_percentile_ms(50.0) >= run.ttft_percentile_ms(50.0) * 0.5);
    }

    #[test]
    fn routing_spreads_load() {
        let model = Arc::new(synthetic_model("micro", 62).unwrap());
        let reqs = synthetic_requests(model.cfg.vocab_size, 16, 4, 2, 10).unwrap();
        let cfg = ServerConfig { workers: 4, kv_tokens: 4096, ..Default::default() };
        let run = serve_requests(model, &cfg, reqs);
        // Every worker should have taken some share under least-loaded.
        let busy = run.per_worker.iter().filter(|m| m.requests > 0).count();
        assert!(busy >= 3, "only {busy} workers used");
    }

    #[test]
    fn single_worker_equals_batcher_semantics() {
        let model = Arc::new(synthetic_model("micro", 63).unwrap());
        let prompt = vec![3u32, 5, 7];
        let want = model.generate_greedy(&prompt, 4);
        let reqs = vec![Request {
            id: 0,
            prompt,
            max_new: 4,
            submitted: Instant::now(),
        }];
        let cfg = ServerConfig { workers: 1, kv_tokens: 4096, ..Default::default() };
        let run = serve_requests(model, &cfg, reqs);
        assert!(want.starts_with(&run.responses[0].tokens) || run.responses[0].tokens == want);
    }
}
