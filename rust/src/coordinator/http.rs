//! Hand-rolled HTTP/1.1 + SSE protocol layer for the serving front end.
//!
//! Dependency-free by construction: requests are parsed straight off a
//! [`std::net::TcpStream`], bodies use [`crate::util::json`], and streamed
//! responses are written as `Transfer-Encoding: chunked` Server-Sent Events.
//! The listener/thread-pool half lives in [`super::server`]; this module is
//! the per-connection state machine and the request/response wire formats.
//!
//! # Connection lifecycle
//!
//! Each accepted connection runs this state machine ([`handle_connection`]):
//!
//! ```text
//!            ┌────────────────────────── keep-alive ─────────────────────┐
//!            ▼                                                           │
//! accept ─► WAIT ─► PARSE ─► ROUTE ─┬─► SUBMIT ─┬─► STREAM (SSE) ────────┤
//!            │        │             │           └─► DRAIN (non-stream) ──┤
//!            │        │             └─► static (healthz/models/admin) ───┘
//!            ▼        ▼
//!          CLOSE ◄── 4xx
//! ```
//!
//! - **WAIT**: poll for the first request byte ([`wait_readable`]) so an idle
//!   keep-alive connection can observe server shutdown within ~10 ms instead
//!   of sleeping through a blocking read. Idle timeout or a half-closed
//!   socket closes the connection silently.
//! - **PARSE**: request line + headers + `Content-Length`-bounded body
//!   ([`read_request`]), with hard caps on line length, header count, and
//!   body size. Malformed input answers with a 4xx and closes.
//! - **SUBMIT**: `POST /v1/completions` maps the JSON body onto
//!   [`GenRequest`]/[`SamplingParams`] ([`parse_completion`]) and submits to
//!   the [`Engine`](super::engine::Engine). [`SubmitError::QueueFull`] → 429,
//!   [`SubmitError::Closed`] → 503; neither produces a stream.
//! - **STREAM / DRAIN**: the accepted [`RequestHandle`] is polled with
//!   [`RequestHandle::recv_timeout`]. Engine events map onto the wire 1:1 —
//!   `Token` becomes one `data: {...}` SSE event (or accumulates, when
//!   `stream=false`), `Finished` becomes the terminal usage event with
//!   `finish_reason` from [`FinishReason::wire_str`] (deadline expiry thus
//!   surfaces as `"deadline"`), followed by `data: [DONE]`. Between events
//!   the socket is probed ([`half_closed`]); a disconnect — detected on a
//!   failed write or a half-closed socket — calls [`RequestHandle::cancel`],
//!   so the batcher frees the request's KV lease within one iteration.
//! - **keep-alive | CLOSE**: HTTP/1.1 defaults to keep-alive (SSE responses
//!   are chunked precisely so the connection survives a completed stream);
//!   `Connection: close`, protocol errors, disconnects, and server shutdown
//!   close instead.
//!
//! Server shutdown (the SIGTERM-equivalent `POST /admin/shutdown`) flips
//! flags in [`ServeCtx`]: `stop` refuses new keep-alive iterations, `abort`
//! cancels in-flight handles; the engine itself then drains via
//! `Engine::shutdown_mode(Drain, ..)` in the caller (see `serve_cmd`).

use super::batcher::{FinishReason, GenRequest, TokenEvent};
use super::engine::{Engine, SubmitError, TryEvent};
use crate::data::{Cat, Vocab};
use crate::model::SamplingParams;
use crate::util::json::{num, obj, s, Json};
use std::io::{BufRead, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Hard caps on inbound requests: one header line, total header count, and
/// the `Content-Length` body. Oversize input answers 431/413 and closes.
pub const MAX_LINE: usize = 8 * 1024;
pub const MAX_HEADERS: usize = 64;

/// Stream-poll granularity: how quickly a handler notices a half-closed
/// socket or a server abort between engine events.
const POLL: Duration = Duration::from_millis(25);

/// How long [`wait_readable`] sleeps between peeks on an idle connection.
const IDLE_TICK: Duration = Duration::from_millis(10);

/// Shared state every connection handler reads: the engine, the tokenizer
/// for text prompts and response text, and the server lifecycle flags.
pub struct ServeCtx {
    pub engine: Arc<Engine>,
    pub vocab: Arc<Vocab>,
    pub vocab_size: usize,
    /// Served under `GET /v1/models` and echoed in every completion.
    pub model_id: String,
    /// Monotonic request-id source shared across connections.
    pub next_id: AtomicU64,
    /// Set on shutdown: no new requests are accepted (keep-alive loops end).
    pub stop: AtomicBool,
    /// Set after the shutdown grace period: in-flight streams cancel now.
    pub abort: AtomicBool,
    /// Set by `POST /admin/shutdown`; the serve loop polls it.
    pub shutdown_req: AtomicBool,
    /// Idle keep-alive window before a quiet connection is closed.
    pub keep_alive: Duration,
    /// `Content-Length` cap for request bodies.
    pub max_body: usize,
    /// Default end-to-end deadline applied when the request carries none.
    pub default_deadline: Option<Duration>,
}

/// A parsed HTTP/1.x request.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub target: String,
    pub http11: bool,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup (first match).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// HTTP/1.1 defaults to keep-alive, HTTP/1.0 to close; an explicit
    /// `Connection` header overrides either way.
    pub fn keep_alive(&self) -> bool {
        match self.header("connection") {
            Some(v) if v.eq_ignore_ascii_case("close") => false,
            Some(v) if v.eq_ignore_ascii_case("keep-alive") => true,
            _ => self.http11,
        }
    }
}

/// Why [`read_request`] did not produce a request.
#[derive(Debug)]
pub enum ReadError {
    /// Clean EOF (or idle cutoff) before any request byte — close silently.
    Closed,
    /// Malformed or oversize request: answer with this status, then close.
    Bad(u16, &'static str, String),
    /// Socket error mid-request — close without a response.
    Io(std::io::Error),
}

fn read_line<R: BufRead>(r: &mut R) -> Result<String, ReadError> {
    let mut buf = Vec::with_capacity(128);
    loop {
        let available = match r.fill_buf() {
            Ok(b) => b,
            Err(e) => return Err(ReadError::Io(e)),
        };
        if available.is_empty() {
            // EOF. Mid-line EOF on a non-empty buffer is a truncated request.
            if buf.is_empty() {
                return Err(ReadError::Closed);
            }
            return Err(ReadError::Bad(400, "Bad Request", "truncated request line".into()));
        }
        let nl = available.iter().position(|&b| b == b'\n');
        let take = nl.map(|i| i + 1).unwrap_or(available.len());
        buf.extend_from_slice(&available[..take]);
        r.consume(take);
        if nl.is_some() {
            break;
        }
        if buf.len() > MAX_LINE {
            return Err(ReadError::Bad(
                431,
                "Request Header Fields Too Large",
                format!("header line exceeds {MAX_LINE} bytes"),
            ));
        }
    }
    if buf.len() > MAX_LINE {
        return Err(ReadError::Bad(
            431,
            "Request Header Fields Too Large",
            format!("header line exceeds {MAX_LINE} bytes"),
        ));
    }
    // Tolerate bare-LF clients; strip the terminator either way.
    while buf.last() == Some(&b'\n') || buf.last() == Some(&b'\r') {
        buf.pop();
    }
    String::from_utf8(buf)
        .map_err(|_| ReadError::Bad(400, "Bad Request", "non-UTF-8 header line".into()))
}

/// Parse one request off the connection: request line, headers, and a
/// `Content-Length`-bounded body. Chunked request bodies are refused (501) —
/// every client this server fronts sends sized bodies.
pub fn read_request<R: BufRead>(r: &mut R, max_body: usize) -> Result<HttpRequest, ReadError> {
    let line = read_line(r)?;
    let mut parts = line.split_ascii_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v)) if parts.next().is_none() => (m, t, v),
        _ => {
            return Err(ReadError::Bad(
                400,
                "Bad Request",
                format!("malformed request line {line:?}"),
            ))
        }
    };
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        other => {
            return Err(ReadError::Bad(
                505,
                "HTTP Version Not Supported",
                format!("unsupported version {other:?}"),
            ))
        }
    };
    let mut headers = Vec::new();
    loop {
        let line = match read_line(r) {
            Ok(l) => l,
            // EOF between headers is still a truncated request.
            Err(ReadError::Closed) => {
                return Err(ReadError::Bad(400, "Bad Request", "truncated headers".into()))
            }
            Err(e) => return Err(e),
        };
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(ReadError::Bad(
                431,
                "Request Header Fields Too Large",
                format!("more than {MAX_HEADERS} headers"),
            ));
        }
        let Some((k, v)) = line.split_once(':') else {
            return Err(ReadError::Bad(400, "Bad Request", format!("malformed header {line:?}")));
        };
        headers.push((k.trim().to_string(), v.trim().to_string()));
    }
    let mut req = HttpRequest {
        method: method.to_string(),
        target: target.to_string(),
        http11,
        headers,
        body: Vec::new(),
    };
    if req.header("transfer-encoding").is_some() {
        return Err(ReadError::Bad(
            501,
            "Not Implemented",
            "chunked request bodies are not supported; send Content-Length".into(),
        ));
    }
    if let Some(cl) = req.header("content-length") {
        let n: usize = cl.parse().map_err(|_| {
            ReadError::Bad(400, "Bad Request", format!("bad Content-Length {cl:?}"))
        })?;
        if n > max_body {
            return Err(ReadError::Bad(
                413,
                "Payload Too Large",
                format!("body of {n} bytes exceeds the {max_body}-byte cap"),
            ));
        }
        let mut body = vec![0u8; n];
        r.read_exact(&mut body).map_err(ReadError::Io)?;
        req.body = body;
    }
    Ok(req)
}

/// Write a plain (non-SSE) response with a sized body.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: {}\r\n\r\n",
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// OpenAI-style error body.
pub fn error_body(status: u16, kind: &str, msg: &str) -> String {
    obj(vec![(
        "error",
        obj(vec![("message", s(msg)), ("type", s(kind)), ("code", num(status as f64))]),
    )])
    .to_string_compact()
}

fn write_error<W: Write>(
    w: &mut W,
    status: u16,
    reason: &str,
    kind: &str,
    msg: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    let body = error_body(status, kind, msg);
    write_response(w, status, reason, "application/json", body.as_bytes(), keep_alive)
}

/// Chunked SSE response writer: one chunk per `data:` event, so each token
/// hits the wire as soon as the engine emits it and the connection can
/// keep-alive after the stream's `0\r\n\r\n` trailer.
pub struct SseWriter<'a, W: Write> {
    w: &'a mut W,
}

impl<'a, W: Write> SseWriter<'a, W> {
    pub fn begin(w: &'a mut W, keep_alive: bool) -> std::io::Result<SseWriter<'a, W>> {
        let head = format!(
            "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
             Cache-Control: no-cache\r\nTransfer-Encoding: chunked\r\nConnection: {}\r\n\r\n",
            if keep_alive { "keep-alive" } else { "close" },
        );
        w.write_all(head.as_bytes())?;
        w.flush()?;
        Ok(SseWriter { w })
    }

    /// Emit one `data: {payload}\n\n` event as one HTTP chunk.
    pub fn event(&mut self, payload: &str) -> std::io::Result<()> {
        let frame = format!("data: {payload}\n\n");
        let chunk = format!("{:x}\r\n{frame}\r\n", frame.len());
        self.w.write_all(chunk.as_bytes())?;
        self.w.flush()
    }

    /// Terminate the chunked body (the connection may then keep-alive).
    pub fn finish(&mut self) -> std::io::Result<()> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

/// A `/v1/completions` body mapped onto engine terms.
pub struct CompletionRequest {
    pub prompt: Vec<u32>,
    pub max_tokens: usize,
    pub sampling: SamplingParams,
    pub stream: bool,
    pub deadline: Option<Duration>,
    pub ttft_deadline: Option<Duration>,
}

fn field_usize(body: &Json, key: &str, default: usize) -> Result<usize, String> {
    match body.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v.as_usize().ok_or_else(|| format!("{key} must be a non-negative integer")),
    }
}

fn field_f32(body: &Json, key: &str, default: f32) -> Result<f32, String> {
    match body.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v.as_f64().map(|x| x as f32).ok_or_else(|| format!("{key} must be a number")),
    }
}

fn token_id(v: &Json, vocab_size: usize) -> Result<u32, String> {
    let id = v
        .as_usize()
        .ok_or_else(|| format!("token ids must be non-negative integers, got {v:?}"))?;
    if id >= vocab_size {
        return Err(format!("token id {id} out of range for vocab of {vocab_size}"));
    }
    Ok(id as u32)
}

/// Map a request body onto a [`CompletionRequest`]. `prompt` is either a
/// string (tokenized with the model's vocab) or an array of token ids;
/// `stop` entries are words or ids. See the serve CLI help for the schema.
pub fn parse_completion(
    body: &Json,
    vocab: &Vocab,
    vocab_size: usize,
) -> Result<CompletionRequest, String> {
    let prompt = match body.get("prompt") {
        Some(Json::Str(text)) => {
            let ids = vocab.tokenize(text);
            if ids.is_empty() {
                return Err(format!("prompt {text:?} produced no tokens under this vocab"));
            }
            ids
        }
        Some(Json::Arr(items)) => {
            if items.is_empty() {
                return Err("prompt must not be empty".into());
            }
            items.iter().map(|v| token_id(v, vocab_size)).collect::<Result<Vec<u32>, _>>()?
        }
        Some(other) => {
            return Err(format!("prompt must be a string or an array of token ids, got {other:?}"))
        }
        None => return Err("missing required field: prompt".into()),
    };
    let max_tokens = field_usize(body, "max_tokens", 16)?;
    let temperature = field_f32(body, "temperature", 0.0)?;
    let top_k = field_usize(body, "top_k", 0)?;
    let top_p = field_f32(body, "top_p", 1.0)?;
    let seed = match body.get("seed") {
        None | Some(Json::Null) => 0u64,
        Some(v) => v
            .as_f64()
            .filter(|x| *x >= 0.0 && x.fract() == 0.0)
            .map(|x| x as u64)
            .ok_or_else(|| "seed must be a non-negative integer".to_string())?,
    };
    let stream = match body.get("stream") {
        None | Some(Json::Null) => false,
        Some(v) => v.as_bool().ok_or_else(|| "stream must be a boolean".to_string())?,
    };
    let stop_tokens = match body.get("stop") {
        None | Some(Json::Null) => Vec::new(),
        Some(Json::Str(word)) => vec![stop_word(vocab, word)?],
        Some(Json::Arr(items)) => items
            .iter()
            .map(|v| match v {
                Json::Str(word) => stop_word(vocab, word),
                other => token_id(other, vocab_size),
            })
            .collect::<Result<Vec<u32>, _>>()?,
        Some(other) => {
            return Err(format!("stop must be a word, a token id array, or null, got {other:?}"))
        }
    };
    let deadline = match field_usize(body, "deadline_ms", 0)? {
        0 => None,
        ms => Some(Duration::from_millis(ms as u64)),
    };
    let ttft_deadline = match field_usize(body, "ttft_deadline_ms", 0)? {
        0 => None,
        ms => Some(Duration::from_millis(ms as u64)),
    };
    Ok(CompletionRequest {
        prompt,
        max_tokens,
        sampling: SamplingParams { temperature, top_k, top_p, seed, stop_tokens },
        stream,
        deadline,
        ttft_deadline,
    })
}

fn stop_word(vocab: &Vocab, word: &str) -> Result<u32, String> {
    vocab.id_of(word).ok_or_else(|| format!("stop word {word:?} is not in the vocab"))
}

/// The text delta for one streamed token: spacing matches
/// [`Vocab::detokenize`] over the generated ids, so concatenating every
/// chunk's `text` reproduces the non-streamed `text` exactly.
pub fn token_text(vocab: &Vocab, index: usize, token: u32) -> String {
    let word = vocab.word(token);
    if index > 0 && vocab.cat_of(token) != Cat::Punct {
        format!(" {word}")
    } else {
        word.to_string()
    }
}

/// Probe for a peer that closed (or half-closed) its end without waking any
/// read we own: a non-blocking one-byte peek. `Ok(0)` is EOF ⇒ the client is
/// gone and the request must be cancelled. Pending request bytes (`Ok(n)`)
/// and `WouldBlock` both mean the peer is still there.
pub fn half_closed(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return false;
    }
    let mut probe = [0u8; 1];
    let gone = match stream.peek(&mut probe) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) => !matches!(e.kind(), std::io::ErrorKind::WouldBlock),
    };
    let _ = stream.set_nonblocking(false);
    gone
}

/// Block until the connection has a request byte to parse, the peer leaves,
/// the idle window lapses, or the server stops. `buffered` short-circuits
/// the probe when the reader already holds pipelined bytes.
fn wait_readable(stream: &TcpStream, ctx: &ServeCtx, buffered: bool) -> bool {
    if buffered {
        return true;
    }
    let t0 = Instant::now();
    loop {
        if ctx.stop.load(Ordering::Relaxed) {
            return false;
        }
        if stream.set_nonblocking(true).is_err() {
            return false;
        }
        let mut probe = [0u8; 1];
        let state = stream.peek(&mut probe);
        let _ = stream.set_nonblocking(false);
        match state {
            Ok(0) => return false,
            Ok(_) => return true,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if t0.elapsed() > ctx.keep_alive {
                    return false;
                }
                std::thread::sleep(IDLE_TICK);
            }
            Err(_) => return false,
        }
    }
}

enum ConnAction {
    Keep,
    Close,
}

/// Drive one connection through the module-doc state machine until it
/// closes. Never panics the worker thread on socket errors — every write is
/// allowed to fail into `Close`.
pub fn handle_connection(stream: TcpStream, ctx: &ServeCtx) {
    let _ = stream.set_nodelay(true);
    // Once bytes start flowing, individual reads/writes get a bounded
    // timeout so a stalled peer cannot wedge a worker thread forever.
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = std::io::BufReader::new(read_half);
    let mut writer = stream;
    loop {
        if !wait_readable(reader.get_ref(), ctx, !reader.buffer().is_empty()) {
            return;
        }
        let req = match read_request(&mut reader, ctx.max_body) {
            Ok(req) => req,
            Err(ReadError::Closed) | Err(ReadError::Io(_)) => return,
            Err(ReadError::Bad(status, reason, msg)) => {
                let _ = write_error(&mut writer, status, reason, "invalid_request", &msg, false);
                return;
            }
        };
        let keep = req.keep_alive() && !ctx.stop.load(Ordering::Relaxed);
        let action = route(&mut writer, ctx, &req, keep);
        match action {
            ConnAction::Keep => continue,
            ConnAction::Close => return,
        }
    }
}

fn route(w: &mut TcpStream, ctx: &ServeCtx, req: &HttpRequest, keep: bool) -> ConnAction {
    let path = req.target.split('?').next().unwrap_or("");
    let outcome = match (req.method.as_str(), path) {
        ("POST", "/v1/completions") => return completions(w, ctx, req, keep),
        ("GET", "/healthz") => healthz(w, ctx, keep),
        ("GET", "/v1/models") => models(w, ctx, keep),
        ("POST", "/admin/shutdown") => {
            ctx.shutdown_req.store(true, Ordering::SeqCst);
            let body = obj(vec![("status", s("draining"))]).to_string_compact();
            write_response(w, 200, "OK", "application/json", body.as_bytes(), false)
                .map(|_| ConnAction::Close)
        }
        ("POST", _) | ("GET", _) => write_error(
            w,
            404,
            "Not Found",
            "invalid_request",
            &format!("no route for {} {}", req.method, path),
            keep,
        )
        .map(|_| if keep { ConnAction::Keep } else { ConnAction::Close }),
        (method, _) => write_error(
            w,
            405,
            "Method Not Allowed",
            "invalid_request",
            &format!("method {method} not supported"),
            keep,
        )
        .map(|_| if keep { ConnAction::Keep } else { ConnAction::Close }),
    };
    outcome.unwrap_or(ConnAction::Close)
}

fn healthz(w: &mut TcpStream, ctx: &ServeCtx, keep: bool) -> std::io::Result<ConnAction> {
    let alive = ctx.engine.alive_workers();
    let ok = alive > 0;
    let body = obj(vec![
        ("status", s(if ok { "ok" } else { "failed" })),
        ("workers", num(ctx.engine.n_workers() as f64)),
        ("alive_workers", num(alive as f64)),
        ("kv_used_tokens", num(ctx.engine.kv_used_tokens() as f64)),
        ("kv_live_leases", num(ctx.engine.kv_live_leases() as f64)),
        ("draining", Json::Bool(ctx.stop.load(Ordering::Relaxed))),
    ])
    .to_string_compact();
    let (status, reason) = if ok { (200, "OK") } else { (503, "Service Unavailable") };
    write_response(w, status, reason, "application/json", body.as_bytes(), keep)
        .map(|_| if keep { ConnAction::Keep } else { ConnAction::Close })
}

fn models(w: &mut TcpStream, ctx: &ServeCtx, keep: bool) -> std::io::Result<ConnAction> {
    let body = obj(vec![
        ("object", s("list")),
        (
            "data",
            Json::Arr(vec![obj(vec![
                ("id", s(&ctx.model_id)),
                ("object", s("model")),
                ("owned_by", s("aser")),
            ])]),
        ),
    ])
    .to_string_compact();
    write_response(w, 200, "OK", "application/json", body.as_bytes(), keep)
        .map(|_| if keep { ConnAction::Keep } else { ConnAction::Close })
}

fn completions(w: &mut TcpStream, ctx: &ServeCtx, req: &HttpRequest, keep: bool) -> ConnAction {
    let fail = |w: &mut TcpStream, msg: &str| {
        let _ = write_error(w, 400, "Bad Request", "invalid_request", msg, keep);
        if keep {
            ConnAction::Keep
        } else {
            ConnAction::Close
        }
    };
    let Ok(text) = std::str::from_utf8(&req.body) else {
        return fail(w, "body is not UTF-8");
    };
    let body = match Json::parse(text) {
        Ok(v) => v,
        Err(e) => return fail(w, &format!("invalid JSON body: {} at byte {}", e.msg, e.pos)),
    };
    let creq = match parse_completion(&body, &ctx.vocab, ctx.vocab_size) {
        Ok(c) => c,
        Err(msg) => return fail(w, &msg),
    };
    let id = ctx.next_id.fetch_add(1, Ordering::Relaxed);
    let prompt_len = creq.prompt.len();
    let mut gen = GenRequest::new(id, creq.prompt, creq.max_tokens);
    gen.sampling = creq.sampling;
    gen.deadline = creq.deadline.or(ctx.default_deadline);
    gen.ttft_deadline = creq.ttft_deadline;
    let handle = match ctx.engine.submit(gen) {
        Ok(h) => h,
        Err(SubmitError::QueueFull(_)) => {
            let _ = write_error(
                w,
                429,
                "Too Many Requests",
                "overloaded",
                "admission queue is full; retry with backoff",
                keep,
            );
            return if keep { ConnAction::Keep } else { ConnAction::Close };
        }
        Err(SubmitError::Closed(_)) => {
            let _ = write_error(
                w,
                503,
                "Service Unavailable",
                "shutting_down",
                "engine is shutting down",
                false,
            );
            return ConnAction::Close;
        }
    };
    if creq.stream {
        stream_completion(w, ctx, handle, id, prompt_len, keep)
    } else {
        blocking_completion(w, ctx, handle, id, prompt_len, keep)
    }
}

fn completion_id(id: u64) -> String {
    format!("cmpl-{id}")
}

fn usage_json(prompt_len: usize, n_tokens: usize) -> Json {
    obj(vec![
        ("prompt_tokens", num(prompt_len as f64)),
        ("completion_tokens", num(n_tokens as f64)),
        ("total_tokens", num((prompt_len + n_tokens) as f64)),
    ])
}

fn stream_completion(
    w: &mut TcpStream,
    ctx: &ServeCtx,
    handle: super::engine::RequestHandle,
    id: u64,
    prompt_len: usize,
    keep: bool,
) -> ConnAction {
    let Ok(mut sse) = SseWriter::begin(w, keep) else {
        handle.cancel();
        return ConnAction::Close;
    };
    loop {
        match handle.recv_timeout(POLL) {
            TryEvent::Event(TokenEvent::PrefillDone { .. }) => {}
            TryEvent::Event(TokenEvent::Token { token, index }) => {
                let chunk = obj(vec![
                    ("id", s(&completion_id(id))),
                    ("object", s("text_completion.chunk")),
                    ("model", s(&ctx.model_id)),
                    (
                        "choices",
                        Json::Arr(vec![obj(vec![
                            ("index", num(0.0)),
                            ("text", s(&token_text(&ctx.vocab, index, token))),
                            ("token_id", num(token as f64)),
                            ("token_index", num(index as f64)),
                        ])]),
                    ),
                ])
                .to_string_compact();
                if sse.event(&chunk).is_err() {
                    // Disconnect detected on write: free the KV lease now.
                    handle.cancel();
                    return ConnAction::Close;
                }
            }
            TryEvent::Event(TokenEvent::Finished { reason, n_tokens, ttft, total }) => {
                let fin = obj(vec![
                    ("id", s(&completion_id(id))),
                    ("object", s("text_completion.chunk")),
                    ("model", s(&ctx.model_id)),
                    (
                        "choices",
                        Json::Arr(vec![obj(vec![
                            ("index", num(0.0)),
                            ("text", s("")),
                            ("finish_reason", s(reason.wire_str())),
                        ])]),
                    ),
                    ("usage", usage_json(prompt_len, n_tokens)),
                    ("ttft_ms", num(ttft.as_secs_f64() * 1e3)),
                    ("total_ms", num(total.as_secs_f64() * 1e3)),
                ])
                .to_string_compact();
                let done =
                    sse.event(&fin).and_then(|_| sse.event("[DONE]")).and_then(|_| sse.finish());
                let draining =
                    ctx.stop.load(Ordering::Relaxed) || ctx.abort.load(Ordering::Relaxed);
                return match done {
                    Ok(()) if keep && !draining => ConnAction::Keep,
                    _ => ConnAction::Close,
                };
            }
            TryEvent::Empty => {
                if ctx.abort.load(Ordering::Relaxed) {
                    // Server shutdown grace expired: cancel and let the
                    // terminal Cancelled event close the stream cleanly.
                    handle.cancel();
                }
                if half_closed(sse.w) {
                    handle.cancel();
                    return ConnAction::Close;
                }
            }
            TryEvent::Closed => {
                // Worker died with no terminal event; report and move on.
                let fin = obj(vec![
                    ("id", s(&completion_id(id))),
                    ("object", s("text_completion.chunk")),
                    (
                        "choices",
                        Json::Arr(vec![obj(vec![
                            ("index", num(0.0)),
                            ("text", s("")),
                            ("finish_reason", s(FinishReason::WorkerFailed.wire_str())),
                        ])]),
                    ),
                ])
                .to_string_compact();
                let done =
                    sse.event(&fin).and_then(|_| sse.event("[DONE]")).and_then(|_| sse.finish());
                return match done {
                    Ok(()) if keep => ConnAction::Keep,
                    _ => ConnAction::Close,
                };
            }
        }
    }
}

fn blocking_completion(
    w: &mut TcpStream,
    ctx: &ServeCtx,
    handle: super::engine::RequestHandle,
    id: u64,
    prompt_len: usize,
    keep: bool,
) -> ConnAction {
    let mut tokens: Vec<u32> = Vec::new();
    let (finish, ttft, total) = loop {
        match handle.recv_timeout(POLL) {
            TryEvent::Event(TokenEvent::PrefillDone { .. }) => {}
            TryEvent::Event(TokenEvent::Token { token, .. }) => tokens.push(token),
            TryEvent::Event(TokenEvent::Finished { reason, ttft, total, .. }) => {
                break (reason, ttft, total)
            }
            TryEvent::Empty => {
                if ctx.abort.load(Ordering::Relaxed) {
                    handle.cancel();
                }
                if half_closed(w) {
                    // Client gone before the response: free the lease and
                    // close; there is nobody to answer.
                    handle.cancel();
                    return ConnAction::Close;
                }
            }
            TryEvent::Closed => {
                break (FinishReason::WorkerFailed, Duration::ZERO, handle.elapsed())
            }
        }
    };
    if finish == FinishReason::Rejected {
        let _ = write_error(
            w,
            400,
            "Bad Request",
            "invalid_request",
            "request rejected at admission: prompt cannot fit the KV window",
            keep,
        );
        return if keep { ConnAction::Keep } else { ConnAction::Close };
    }
    let body = obj(vec![
        ("id", s(&completion_id(id))),
        ("object", s("text_completion")),
        ("model", s(&ctx.model_id)),
        (
            "choices",
            Json::Arr(vec![obj(vec![
                ("index", num(0.0)),
                ("text", s(&ctx.vocab.detokenize(&tokens))),
                ("token_ids", Json::Arr(tokens.iter().map(|&t| num(t as f64)).collect())),
                ("finish_reason", s(finish.wire_str())),
            ])]),
        ),
        ("usage", usage_json(prompt_len, tokens.len())),
        ("ttft_ms", num(ttft.as_secs_f64() * 1e3)),
        ("total_ms", num(total.as_secs_f64() * 1e3)),
    ])
    .to_string_compact();
    let draining = ctx.stop.load(Ordering::Relaxed) || ctx.abort.load(Ordering::Relaxed);
    match write_response(w, 200, "OK", "application/json", body.as_bytes(), keep) {
        Ok(()) if keep && !draining => ConnAction::Keep,
        _ => ConnAction::Close,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<HttpRequest, ReadError> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()), 1024)
    }

    #[test]
    fn parses_request_line_headers_and_body() {
        let req = parse(
            "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\n\
             Content-Length: 4\r\n\r\nabcd",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.target, "/v1/completions");
        assert!(req.http11);
        assert_eq!(req.header("content-type"), Some("application/json"));
        assert_eq!(req.header("CONTENT-LENGTH"), Some("4"));
        assert_eq!(req.body, b"abcd");
        assert!(req.keep_alive());
    }

    #[test]
    fn connection_header_controls_keep_alive() {
        let req = parse("GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        assert!(!req.keep_alive());
        let req = parse("GET /healthz HTTP/1.0\r\n\r\n").unwrap();
        assert!(!req.keep_alive());
        let req = parse("GET /healthz HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap();
        assert!(req.keep_alive());
    }

    #[test]
    fn malformed_and_oversize_requests_are_rejected() {
        assert!(matches!(parse("NOPE\r\n\r\n"), Err(ReadError::Bad(400, ..))));
        assert!(matches!(parse(""), Err(ReadError::Closed)));
        assert!(matches!(
            parse("GET / HTTP/2.0\r\n\r\n"),
            Err(ReadError::Bad(505, ..))
        ));
        // Body over the cap (max_body = 1024 in `parse`).
        let big = format!("POST / HTTP/1.1\r\nContent-Length: 4096\r\n\r\n{}", "x".repeat(4096));
        assert!(matches!(parse(&big), Err(ReadError::Bad(413, ..))));
        // Header line over MAX_LINE.
        let long = format!("GET / HTTP/1.1\r\nX: {}\r\n\r\n", "y".repeat(MAX_LINE + 1));
        assert!(matches!(parse(&long), Err(ReadError::Bad(431, ..))));
        // Truncated body: Content-Length promises more than the wire holds.
        assert!(matches!(
            parse("POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc"),
            Err(ReadError::Io(_))
        ));
    }

    #[test]
    fn completion_body_maps_onto_engine_terms() {
        let vocab = Vocab::new(128);
        let body = Json::parse(
            r#"{"prompt": [3, 5, 7], "max_tokens": 9, "temperature": 0.75,
                "top_k": 40, "top_p": 0.9, "seed": 11, "stream": true,
                "deadline_ms": 250}"#,
        )
        .unwrap();
        let creq = parse_completion(&body, &vocab, 128).unwrap();
        assert_eq!(creq.prompt, vec![3, 5, 7]);
        assert_eq!(creq.max_tokens, 9);
        assert!((creq.sampling.temperature - 0.75).abs() < 1e-6);
        assert_eq!(creq.sampling.top_k, 40);
        assert_eq!(creq.sampling.seed, 11);
        assert!(creq.stream);
        assert_eq!(creq.deadline, Some(Duration::from_millis(250)));
        assert_eq!(creq.ttft_deadline, None);
    }

    #[test]
    fn text_prompt_and_stop_words_use_the_vocab() {
        let vocab = Vocab::new(128);
        let text = vocab.detokenize(&[5, 9, 13]);
        let stop = vocab.word(20).to_string();
        let body = obj(vec![
            ("prompt", s(&text)),
            ("stop", Json::Arr(vec![s(&stop), num(21.0)])),
        ]);
        let creq = parse_completion(&body, &vocab, 128).unwrap();
        assert!(!creq.prompt.is_empty());
        assert_eq!(creq.sampling.stop_tokens, vec![20, 21]);
        assert_eq!(creq.max_tokens, 16, "OpenAI-style default");
        assert!(!creq.stream);
    }

    #[test]
    fn completion_body_errors_are_specific() {
        let vocab = Vocab::new(128);
        for (body, needle) in [
            (r#"{}"#, "missing required field: prompt"),
            (r#"{"prompt": []}"#, "must not be empty"),
            (r#"{"prompt": [99999]}"#, "out of range"),
            (r#"{"prompt": [1], "max_tokens": -3}"#, "non-negative integer"),
            (r#"{"prompt": [1], "stream": 7}"#, "boolean"),
            (r#"{"prompt": [1], "stop": ["zzzznotaword"]}"#, "not in the vocab"),
        ] {
            let err = parse_completion(&Json::parse(body).unwrap(), &vocab, 128).unwrap_err();
            assert!(err.contains(needle), "{body} → {err}");
        }
    }

    #[test]
    fn streamed_token_texts_concatenate_to_detokenize() {
        let vocab = Vocab::new(128);
        let ids = [7u32, 19, 3, 42, 99, 5];
        let joined: String =
            ids.iter().enumerate().map(|(i, &t)| token_text(&vocab, i, t)).collect();
        assert_eq!(joined, vocab.detokenize(&ids));
    }

    #[test]
    fn sse_writer_frames_events_as_chunks() {
        let mut out: Vec<u8> = Vec::new();
        {
            let mut sse = SseWriter::begin(&mut out, true).unwrap();
            sse.event("{\"x\":1}").unwrap();
            sse.event("[DONE]").unwrap();
            sse.finish().unwrap();
        }
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Transfer-Encoding: chunked"));
        assert!(text.contains("Content-Type: text/event-stream"));
        // Each event is one correctly sized chunk.
        let frame = "data: {\"x\":1}\n\n";
        assert!(text.contains(&format!("{:x}\r\n{frame}\r\n", frame.len())));
        assert!(text.contains("data: [DONE]\n\n"));
        assert!(text.ends_with("0\r\n\r\n"));
    }

    #[test]
    fn error_bodies_are_valid_json() {
        let body = error_body(429, "overloaded", "queue \"full\"\n");
        let v = Json::parse(&body).unwrap();
        assert_eq!(v.get("error").unwrap().int("code").unwrap(), 429);
        assert_eq!(v.get("error").unwrap().str_field("message").unwrap(), "queue \"full\"\n");
    }
}
