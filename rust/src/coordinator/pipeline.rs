//! Quantization pipeline orchestrator.
//!
//! Drives the full PTQ flow: load model → calibrate once → quantize every
//! linear layer with the selected method (layer jobs dispatched through the
//! thread pool) → swap quantized layers into the model → report per-layer
//! error metrics. Calibration statistics are computed once and shared by
//! all methods so comparisons in the tables are apples-to-apples.

use crate::calib::{calib_sequences, calibrate, CalibConfig};
use crate::methods::{layer_error_rel, LayerCalib, PtqMethod, QuantizedLinear};
use crate::model::{layer_key, Gpt, Linear, LINEAR_NAMES};
use crate::quant::Precision;
use crate::util::pool::scope_map;
use anyhow::Result;
use std::collections::BTreeMap;
use std::time::Instant;

/// Per-layer quantization outcome (for reports and Fig. 6).
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub key: String,
    pub rel_error: f32,
    pub rank: usize,
    pub extra_params: usize,
    pub millis: f64,
}

/// Whole-run outcome.
pub struct PipelineReport {
    pub method: String,
    pub precision: Precision,
    pub layers: Vec<LayerReport>,
    pub total_extra_params: usize,
    pub base_params: usize,
    pub wall_ms: f64,
}

impl PipelineReport {
    /// +FLOPs overhead (%) of the compensation branches vs the dense model,
    /// as in the paper's Table 4 (2·r·(d_in+d_out) per token vs 2·d_in·d_out).
    pub fn flops_overhead_pct(&self) -> f64 {
        100.0 * self.total_extra_params as f64 / self.base_params as f64
    }

    pub fn mean_rel_error(&self) -> f32 {
        self.layers.iter().map(|l| l.rel_error).sum::<f32>() / self.layers.len().max(1) as f32
    }

    pub fn mean_rank(&self) -> f64 {
        self.layers.iter().map(|l| l.rank as f64).sum::<f64>() / self.layers.len().max(1) as f64
    }
}

/// Calibration statistics for a model, reusable across methods.
pub type CalibStats = BTreeMap<String, LayerCalib>;

/// Run calibration over the model using corpus `profile`.
pub fn calibrate_model(model: &Gpt, profile: &str, cfg: &CalibConfig) -> Result<CalibStats> {
    let seqs = calib_sequences(model.cfg.vocab_size, profile, cfg)?;
    Ok(calibrate(model, &seqs, cfg))
}

/// Quantize every linear layer of `model` in place. Layer jobs run on the
/// scoped thread pool (`threads=0` ⇒ hardware parallelism).
pub fn quantize_model(
    model: &mut Gpt,
    stats: &CalibStats,
    method: &dyn PtqMethod,
    prec: Precision,
    threads: usize,
) -> Result<PipelineReport> {
    let t0 = Instant::now();
    let n_layers = model.cfg.n_layers;
    // Collect job descriptors: (key, block, name, weight).
    let mut jobs: Vec<(String, usize, &'static str)> = Vec::new();
    for l in 0..n_layers {
        for name in LINEAR_NAMES {
            jobs.push((layer_key(l, name), l, name));
        }
    }
    // Snapshot dense weights (read-only view for workers).
    let weights: Vec<&crate::tensor::Matrix> = jobs
        .iter()
        .map(|(_, l, name)| {
            model
                .get_linear(*l, name)
                .dense_weight()
                .expect("quantize_model requires a dense model")
        })
        .collect();

    let results: Vec<(QuantizedLinear, LayerReport)> = scope_map(jobs.len(), threads, |i| {
        let (key, _, _) = &jobs[i];
        let w = weights[i];
        let calib = stats.get(key).unwrap_or_else(|| panic!("no calibration for {key}"));
        let t = Instant::now();
        let q = method.quantize_layer(w, calib, prec);
        let rel = layer_error_rel(w, &q, &calib.x);
        let rep = LayerReport {
            key: key.clone(),
            rel_error: rel,
            rank: q.rank(),
            extra_params: q.extra_params(),
            millis: t.elapsed().as_secs_f64() * 1e3,
        };
        (q, rep)
    });

    let mut layers = Vec::with_capacity(results.len());
    let mut total_extra = 0usize;
    for ((_, l, name), (q, rep)) in jobs.iter().zip(results) {
        total_extra += rep.extra_params;
        layers.push(rep);
        // `quantized` tile-packs the weight for the batched serve kernel
        // once here, off the request path.
        model.set_linear(*l, name, Linear::quantized(q));
    }
    Ok(PipelineReport {
        method: method.name(),
        precision: prec,
        layers,
        total_extra_params: total_extra,
        base_params: model.cfg.block_params(),
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    })
}

/// Convenience: full flow for one (model, method, precision) combo starting
/// from a dense model. Returns the quantized model + report.
pub fn run_ptq(
    mut model: Gpt,
    stats: &CalibStats,
    method: &dyn PtqMethod,
    prec: Precision,
    threads: usize,
) -> Result<(Gpt, PipelineReport)> {
    let report = quantize_model(&mut model, stats, method, prec, threads)?;
    Ok((model, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::methods::{method_by_name, RankPolicy};
    use crate::model::synthetic_model;

    fn quick_calib(model: &Gpt) -> CalibStats {
        let cfg = CalibConfig { n_seqs: 6, seq_len: 24, max_sample: 64, seed: 3 };
        calibrate_model(model, "wiki", &cfg).unwrap()
    }

    #[test]
    fn pipeline_quantizes_all_layers() {
        let model = synthetic_model("micro", 31).unwrap();
        let stats = quick_calib(&model);
        let method = method_by_name("rtn", RankPolicy::Fixed(8), 4).unwrap();
        let (qm, rep) = run_ptq(model, &stats, method.as_ref(), Precision::w4a8(), 1).unwrap();
        assert_eq!(rep.layers.len(), qm.cfg.n_layers * 4);
        assert!(rep.layers.iter().all(|l| l.rel_error.is_finite()));
        // All linears are quantized now.
        for l in 0..qm.cfg.n_layers {
            for name in LINEAR_NAMES {
                assert!(qm.get_linear(l, name).dense_weight().is_none(), "L{l}.{name}");
            }
        }
    }

    #[test]
    fn aser_pipeline_lower_error_than_rtn() {
        let model = synthetic_model("micro", 32).unwrap();
        let stats = quick_calib(&model);
        let prec = Precision::w4a8();
        let rtn = method_by_name("rtn", RankPolicy::Fixed(8), 4).unwrap();
        let aser = method_by_name("aser", RankPolicy::Fixed(8), 4).unwrap();
        let m1 = synthetic_model("micro", 32).unwrap();
        let (_, rep_rtn) = run_ptq(m1, &stats, rtn.as_ref(), prec, 1).unwrap();
        let m2 = synthetic_model("micro", 32).unwrap();
        let (_, rep_aser) = run_ptq(m2, &stats, aser.as_ref(), prec, 1).unwrap();
        assert!(
            rep_aser.mean_rel_error() < rep_rtn.mean_rel_error(),
            "aser {} !< rtn {}",
            rep_aser.mean_rel_error(),
            rep_rtn.mean_rel_error()
        );
        assert!(rep_aser.total_extra_params > 0);
        assert!(rep_rtn.total_extra_params == 0);
    }

    #[test]
    fn quantized_model_still_generates() {
        let model = synthetic_model("micro", 33).unwrap();
        let stats = quick_calib(&model);
        let method = method_by_name("aser", RankPolicy::Fixed(4), 2).unwrap();
        let (qm, _) = run_ptq(model, &stats, method.as_ref(), Precision::w4a8(), 1).unwrap();
        let out = qm.generate_greedy(&[1, 2, 3], 5);
        assert_eq!(out.len(), 5);
    }

    #[test]
    fn overhead_accounting_matches_rank() {
        let model = synthetic_model("micro", 34).unwrap();
        let stats = quick_calib(&model);
        let method = method_by_name("lorc", RankPolicy::Fixed(4), 0).unwrap();
        let (qm, rep) = run_ptq(model, &stats, method.as_ref(), Precision::w4a8(), 1).unwrap();
        // LoRC at fixed rank 4: extra params = Σ 4·(d_in + d_out).
        let mut want = 0usize;
        for l in 0..qm.cfg.n_layers {
            for name in LINEAR_NAMES {
                let lin = qm.get_linear(l, name);
                want += 4 * (lin.in_features() + lin.out_features());
            }
        }
        assert_eq!(rep.total_extra_params, want);
        assert!(rep.flops_overhead_pct() > 0.0);
    }
}
