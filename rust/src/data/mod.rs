//! Synthetic data substrate: structured vocabulary, grammar-driven corpora
//! (wiki/c4/ptb-like profiles), tokenization.

pub mod corpus;
pub mod vocab;

pub use corpus::{corpus, Corpus, CorpusProfile};
pub use vocab::{Cat, Vocab, BOS, EOS, PAD};
