//! Structured synthetic vocabulary.
//!
//! Token ids are partitioned into grammatical categories with *classes*
//! inside each category. The grammar (see `corpus`) enforces agreement rules
//! between classes (verb class must match subject-noun class; determiner
//! number must match noun parity), giving a small transformer something real
//! to learn — which is what makes perplexity and the zero-shot tasks
//! sensitive to quantization damage.
//!
//! Word surface forms are synthesized from syllables so the serving API can
//! speak text instead of raw ids.

/// Category layout within the id space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cat {
    Special,
    Punct,
    Det,
    Noun,
    Verb,
    Adj,
    Adv,
    Name,
}

pub const BOS: u32 = 0;
pub const EOS: u32 = 1;
pub const PAD: u32 = 2;

/// Number of agreement classes for nouns/verbs/adjs.
pub const N_CLASSES: usize = 8;

#[derive(Clone, Debug)]
pub struct Vocab {
    pub size: usize,
    /// [start, end) per category in the order: special, punct, det, noun,
    /// verb, adj, adv, name.
    ranges: [(u32, u32); 8],
    words: Vec<String>,
}

impl Vocab {
    /// Deterministic layout for a given vocab size (≥ 128).
    pub fn new(size: usize) -> Vocab {
        assert!(size >= 128, "vocab too small: {size}");
        let n = size as u32;
        // Fixed small sections + proportional big ones.
        let special = (0u32, 3u32);
        let punct = (3, 8); // . , ; ! ?
        let det = (8, 16); // 4 singular + 4 plural
        let rest = n - 16;
        let n_noun = rest * 40 / 100;
        let n_verb = rest * 25 / 100;
        let n_adj = rest * 15 / 100;
        let n_adv = rest * 8 / 100;
        let noun = (16, 16 + n_noun);
        let verb = (noun.1, noun.1 + n_verb);
        let adj = (verb.1, verb.1 + n_adj);
        let adv = (adj.1, adj.1 + n_adv);
        let name = (adv.1, n);
        let ranges = [special, punct, det, noun, verb, adj, adv, name];
        let mut words = Vec::with_capacity(size);
        for id in 0..n {
            words.push(surface_form(id, &ranges));
        }
        Vocab { size, ranges, words }
    }

    fn range(&self, cat: Cat) -> (u32, u32) {
        self.ranges[cat as usize]
    }

    pub fn cat_of(&self, id: u32) -> Cat {
        for (i, &(a, b)) in self.ranges.iter().enumerate() {
            if id >= a && id < b {
                return [
                    Cat::Special,
                    Cat::Punct,
                    Cat::Det,
                    Cat::Noun,
                    Cat::Verb,
                    Cat::Adj,
                    Cat::Adv,
                    Cat::Name,
                ][i];
            }
        }
        Cat::Special
    }

    pub fn count(&self, cat: Cat) -> usize {
        let (a, b) = self.range(cat);
        (b - a) as usize
    }

    /// k-th token of a category (k < count).
    pub fn nth(&self, cat: Cat, k: usize) -> u32 {
        let (a, b) = self.range(cat);
        assert!(k < (b - a) as usize, "{cat:?} index {k} out of range");
        a + k as u32
    }

    /// Index of a token within its category.
    pub fn index_in_cat(&self, id: u32) -> usize {
        let (a, _) = self.range(self.cat_of(id));
        (id - a) as usize
    }

    /// Agreement class of a noun/verb/adjective token. Nouns come in
    /// (singular, plural) pairs sharing a class — parity encodes number,
    /// `idx/2` encodes class — so class and number are independent.
    pub fn class_of(&self, id: u32) -> usize {
        let idx = self.index_in_cat(id);
        match self.cat_of(id) {
            Cat::Noun => (idx / 2) % N_CLASSES,
            _ => idx % N_CLASSES,
        }
    }

    /// Nouns use parity for grammatical number: even index = singular.
    pub fn is_plural_noun(&self, id: u32) -> bool {
        debug_assert_eq!(self.cat_of(id), Cat::Noun);
        self.index_in_cat(id) % 2 == 1
    }

    /// Determiners: first half singular, second half plural.
    pub fn det_for(&self, plural: bool, k: usize) -> u32 {
        let n = self.count(Cat::Det) / 2;
        self.nth(Cat::Det, if plural { n + k % n } else { k % n })
    }

    pub fn is_plural_det(&self, id: u32) -> bool {
        debug_assert_eq!(self.cat_of(id), Cat::Det);
        self.index_in_cat(id) >= self.count(Cat::Det) / 2
    }

    pub fn word(&self, id: u32) -> &str {
        &self.words[id as usize]
    }

    pub fn id_of(&self, word: &str) -> Option<u32> {
        // Vocabularies are small; linear scan is fine for the text API.
        self.words.iter().position(|w| w == word).map(|i| i as u32)
    }

    pub fn detokenize(&self, ids: &[u32]) -> String {
        let mut out = String::new();
        for (i, &id) in ids.iter().enumerate() {
            if i > 0 && self.cat_of(id) != Cat::Punct {
                out.push(' ');
            }
            out.push_str(self.word(id));
        }
        out
    }

    pub fn tokenize(&self, text: &str) -> Vec<u32> {
        text.split_whitespace().filter_map(|w| self.id_of(w.trim_matches(['.', ',']))).collect()
    }
}

/// Deterministic pronounceable surface form per id.
fn surface_form(id: u32, ranges: &[(u32, u32); 8]) -> String {
    const ONSETS: [&str; 12] =
        ["b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t"];
    const VOWELS: [&str; 5] = ["a", "e", "i", "o", "u"];
    const CODAS: [&str; 6] = ["", "n", "r", "s", "l", "k"];
    match id {
        0 => return "<bos>".into(),
        1 => return "<eos>".into(),
        2 => return "<pad>".into(),
        _ => {}
    }
    if id >= ranges[1].0 && id < ranges[1].1 {
        return [".", ",", ";", "!", "?"][(id - ranges[1].0) as usize].into();
    }
    // 2-3 syllables keyed by id; category prefix letter keeps words unique
    // across categories even when the syllable hash collides.
    let cat_idx = ranges.iter().position(|&(a, b)| id >= a && id < b).unwrap_or(7);
    let mut h = (id as u64).wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(cat_idx as u64);
    let mut w = String::new();
    let syls = 2 + (h % 2) as usize;
    for _ in 0..syls {
        h = h.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        w.push_str(ONSETS[(h >> 33) as usize % ONSETS.len()]);
        w.push_str(VOWELS[(h >> 23) as usize % VOWELS.len()]);
        w.push_str(CODAS[(h >> 13) as usize % CODAS.len()]);
    }
    format!("{w}{id}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_covers_vocab() {
        let v = Vocab::new(512);
        assert_eq!(v.size, 512);
        let mut total = 0;
        for cat in [Cat::Special, Cat::Punct, Cat::Det, Cat::Noun, Cat::Verb, Cat::Adj, Cat::Adv, Cat::Name] {
            total += v.count(cat);
        }
        assert_eq!(total, 512);
        assert_eq!(v.cat_of(BOS), Cat::Special);
        assert!(v.count(Cat::Noun) > 100);
    }

    #[test]
    fn class_and_number_rules() {
        let v = Vocab::new(512);
        let n0 = v.nth(Cat::Noun, 0);
        let n1 = v.nth(Cat::Noun, 1);
        assert!(!v.is_plural_noun(n0));
        assert!(v.is_plural_noun(n1));
        assert_eq!(v.class_of(n0), 0);
        assert_eq!(v.class_of(n1), 0, "sg/pl pair shares class");
        assert_eq!(v.class_of(v.nth(Cat::Noun, 9)), 4);
        assert_eq!(v.class_of(v.nth(Cat::Verb, 9)), 1);
        let d_sg = v.det_for(false, 0);
        let d_pl = v.det_for(true, 0);
        assert!(!v.is_plural_det(d_sg));
        assert!(v.is_plural_det(d_pl));
    }

    #[test]
    fn words_unique_and_roundtrip() {
        let v = Vocab::new(256);
        let mut seen = std::collections::HashSet::new();
        for id in 0..256u32 {
            assert!(seen.insert(v.word(id).to_string()), "dup word {}", v.word(id));
        }
        for id in [5u32, 20, 100, 255] {
            assert_eq!(v.id_of(v.word(id)), Some(id));
        }
    }

    #[test]
    fn detokenize_readable() {
        let v = Vocab::new(512);
        let ids = vec![v.nth(Cat::Det, 0), v.nth(Cat::Noun, 4), v.nth(Cat::Verb, 4), v.nth(Cat::Punct, 0)];
        let text = v.detokenize(&ids);
        assert!(text.ends_with('.'));
        assert!(text.split(' ').count() >= 3);
    }
}
