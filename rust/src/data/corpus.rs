//! Synthetic corpora with controllable statistics.
//!
//! Three profiles stand in for the paper's evaluation sets (DESIGN.md §3):
//! - `wiki`  — WikiText2-like: broad Zipfian vocab, medium sentences, clean.
//! - `c4`    — C4-like: noisier (agreement violations, ragged lengths).
//! - `ptb`   — PTB-like: narrower effective vocab, short formal sentences.
//!
//! Sentences are drawn from a probabilistic grammar with agreement rules
//! (verb class = subject-noun class, determiner number = noun number,
//! adjective class compatible with the noun). The rules are what the tiny
//! pretrained models learn; quantization damage shows up as broken
//! agreement → higher perplexity and lower task accuracy.

use super::vocab::{Cat, Vocab, EOS, N_CLASSES};
use crate::util::rng::{Pcg64, ZipfSampler};

#[derive(Clone, Debug)]
pub struct CorpusProfile {
    pub name: String,
    /// Zipf exponent within each category.
    pub zipf_s: f64,
    /// Probability of violating an agreement rule (corpus noise).
    pub noise: f64,
    /// Probability an adjective precedes the noun.
    pub p_adj: f64,
    /// Probability of an adverb after the verb.
    pub p_adv: f64,
    /// Sentences per "document" (EOS separated).
    pub sents_per_doc: (usize, usize),
    /// Fraction of the noun/name vocabulary actually used (narrow corpora
    /// use fewer types).
    pub vocab_frac: f64,
}

impl CorpusProfile {
    pub fn by_name(name: &str) -> anyhow::Result<CorpusProfile> {
        Ok(match name {
            "wiki" | "wikitext2" => CorpusProfile {
                name: "wiki".into(),
                zipf_s: 1.05,
                noise: 0.02,
                p_adj: 0.45,
                p_adv: 0.25,
                sents_per_doc: (3, 9),
                vocab_frac: 1.0,
            },
            "c4" => CorpusProfile {
                name: "c4".into(),
                zipf_s: 0.9,
                noise: 0.10,
                p_adj: 0.35,
                p_adv: 0.35,
                sents_per_doc: (1, 6),
                vocab_frac: 1.0,
            },
            "ptb" => CorpusProfile {
                name: "ptb".into(),
                zipf_s: 1.2,
                noise: 0.01,
                p_adj: 0.55,
                p_adv: 0.15,
                sents_per_doc: (2, 5),
                vocab_frac: 0.5,
            },
            other => anyhow::bail!("unknown corpus '{other}'"),
        })
    }

    pub fn all() -> Vec<&'static str> {
        vec!["wiki", "c4", "ptb"]
    }
}

/// Sentence/stream generator over a vocabulary.
pub struct Corpus {
    pub vocab: Vocab,
    pub profile: CorpusProfile,
    noun_z: ZipfSampler,
    verb_z: ZipfSampler,
    adj_z: ZipfSampler,
    adv_z: ZipfSampler,
}

impl Corpus {
    pub fn new(vocab: Vocab, profile: CorpusProfile) -> Corpus {
        let lim = |n: usize| {
            ((n as f64 * profile.vocab_frac) as usize).max(N_CLASSES * 2).min(n)
        };
        let noun_z = ZipfSampler::new(lim(vocab.count(Cat::Noun)), profile.zipf_s);
        let verb_z = ZipfSampler::new(lim(vocab.count(Cat::Verb)), profile.zipf_s);
        let adj_z = ZipfSampler::new(lim(vocab.count(Cat::Adj)), profile.zipf_s);
        let adv_z = ZipfSampler::new(lim(vocab.count(Cat::Adv)), profile.zipf_s);
        Corpus { vocab, profile, noun_z, verb_z, adj_z, adv_z }
    }

    /// Draw a category token of a specific agreement class.
    fn draw_classed(&self, rng: &mut Pcg64, cat: Cat, sampler: &ZipfSampler, class: usize) -> u32 {
        // Rejection-sample the Zipf draw until the class matches (classes
        // are index mod N_CLASSES so acceptance is ~1/8; cheap).
        for _ in 0..64 {
            let k = sampler.sample(rng);
            if k % N_CLASSES == class {
                return self.vocab.nth(cat, k);
            }
        }
        // Fallback: first token of that class.
        self.vocab.nth(cat, class)
    }

    fn draw_noun_with(&self, rng: &mut Pcg64, plural: bool) -> u32 {
        for _ in 0..64 {
            let k = self.noun_z.sample(rng);
            if (k % 2 == 1) == plural {
                return self.vocab.nth(Cat::Noun, k);
            }
        }
        self.vocab.nth(Cat::Noun, if plural { 1 } else { 0 })
    }

    /// One grammatical sentence (possibly with profile-level noise).
    /// Template: DET [ADJ] NOUN VERB [ADV] DET [ADJ] NOUN PUNCT
    pub fn sentence(&self, rng: &mut Pcg64) -> Vec<u32> {
        let v = &self.vocab;
        let p = &self.profile;
        let mut out = Vec::with_capacity(10);
        let noisy = |rng: &mut Pcg64| rng.f64() < p.noise;

        // Subject NP.
        let subj_plural = rng.f64() < 0.4;
        let subj = self.draw_noun_with(rng, subj_plural);
        let det_number = if noisy(rng) { !subj_plural } else { subj_plural };
        out.push(v.det_for(det_number, rng.below(4)));
        if rng.f64() < p.p_adj {
            let class = if noisy(rng) {
                rng.below(N_CLASSES)
            } else {
                v.class_of(subj) % (N_CLASSES / 2) // adj classes are coarser
            };
            out.push(self.draw_classed(rng, Cat::Adj, &self.adj_z, class));
        }
        out.push(subj);
        // Verb agrees with the subject class.
        let vclass = if noisy(rng) { rng.below(N_CLASSES) } else { v.class_of(subj) };
        out.push(self.draw_classed(rng, Cat::Verb, &self.verb_z, vclass));
        if rng.f64() < p.p_adv {
            out.push(v.nth(Cat::Adv, self.adv_z.sample(rng)));
        }
        // Object NP (free class).
        let obj_plural = rng.f64() < 0.4;
        let obj = self.draw_noun_with(rng, obj_plural);
        out.push(v.det_for(obj_plural, rng.below(4)));
        out.push(obj);
        // Punctuation: mostly '.'.
        let p_idx = if rng.f64() < 0.85 { 0 } else { rng.below(v.count(Cat::Punct)) };
        out.push(v.nth(Cat::Punct, p_idx));
        out
    }

    /// Token stream of ~`n_tokens` (documents joined by EOS).
    pub fn stream(&self, rng: &mut Pcg64, n_tokens: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(n_tokens + 16);
        while out.len() < n_tokens {
            let (lo, hi) = self.profile.sents_per_doc;
            let n_sents = lo + rng.below(hi - lo + 1);
            for _ in 0..n_sents {
                out.extend(self.sentence(rng));
            }
            out.push(EOS);
        }
        out.truncate(n_tokens);
        out
    }

    /// Fixed-length training batches (seq_len + 1 tokens each, for
    /// next-token targets).
    pub fn batches(&self, rng: &mut Pcg64, n_batches: usize, seq_len: usize) -> Vec<Vec<u32>> {
        let stream = self.stream(rng, n_batches * (seq_len + 1) + 1);
        (0..n_batches)
            .map(|i| stream[i * (seq_len + 1)..(i + 1) * (seq_len + 1) + 1.min(0)].to_vec())
            .map(|mut b| {
                b.truncate(seq_len + 1);
                b
            })
            .collect()
    }
}

/// Convenience: build corpus by names.
pub fn corpus(vocab_size: usize, profile_name: &str) -> anyhow::Result<Corpus> {
    Ok(Corpus::new(Vocab::new(vocab_size), CorpusProfile::by_name(profile_name)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sentences_follow_agreement_when_noise_free() {
        let mut profile = CorpusProfile::by_name("wiki").unwrap();
        profile.noise = 0.0;
        let c = Corpus::new(Vocab::new(512), profile);
        let mut rng = Pcg64::seed(151);
        for _ in 0..200 {
            let s = c.sentence(&mut rng);
            // Find subject noun (first noun) and the verb after it.
            let v = &c.vocab;
            let noun_pos = s.iter().position(|&t| v.cat_of(t) == Cat::Noun).unwrap();
            let verb_pos = s.iter().position(|&t| v.cat_of(t) == Cat::Verb).unwrap();
            assert!(verb_pos > noun_pos);
            assert_eq!(
                v.class_of(s[noun_pos]),
                v.class_of(s[verb_pos]),
                "agreement violated in {s:?}"
            );
            // Det number matches subject noun.
            let det = s[0];
            assert_eq!(v.is_plural_det(det), v.is_plural_noun(s[noun_pos]));
        }
    }

    #[test]
    fn stream_has_requested_length_and_eos() {
        let c = corpus(512, "c4").unwrap();
        let mut rng = Pcg64::seed(152);
        let s = c.stream(&mut rng, 2000);
        assert_eq!(s.len(), 2000);
        assert!(s.contains(&EOS));
        assert!(s.iter().all(|&t| (t as usize) < 512));
    }

    #[test]
    fn profiles_differ_statistically() {
        let mut rng = Pcg64::seed(153);
        let wiki = corpus(512, "wiki").unwrap().stream(&mut rng, 5000);
        let mut rng2 = Pcg64::seed(153);
        let ptb = corpus(512, "ptb").unwrap().stream(&mut rng2, 5000);
        let types = |s: &[u32]| s.iter().collect::<std::collections::HashSet<_>>().len();
        // ptb uses a narrower vocabulary.
        assert!(types(&ptb) < types(&wiki), "ptb {} !< wiki {}", types(&ptb), types(&wiki));
    }

    #[test]
    fn batches_shape() {
        let c = corpus(256, "wiki").unwrap();
        let mut rng = Pcg64::seed(154);
        let b = c.batches(&mut rng, 5, 32);
        assert_eq!(b.len(), 5);
        assert!(b.iter().all(|x| x.len() == 33));
    }

    #[test]
    fn deterministic_given_seed() {
        let c = corpus(512, "wiki").unwrap();
        let a = c.stream(&mut Pcg64::seed(7), 500);
        let b = c.stream(&mut Pcg64::seed(7), 500);
        assert_eq!(a, b);
    }

    #[test]
    fn unknown_profile_rejected() {
        assert!(CorpusProfile::by_name("imagenet").is_err());
    }
}
