//! # ASER — Activation Smoothing and Error Reconstruction
//!
//! Full-system reproduction of "ASER: Activation Smoothing and Error
//! Reconstruction for Large Language Model Quantization" (AAAI 2025).
//!
//! Architecture (three layers, python never on the request path):
//! - **L3 (this crate)**: quantization pipeline coordinator, serving runtime
//!   (router / batcher / KV-cache), evaluation + benchmark harness, and every
//!   substrate they need (tensor/linalg/quant/model/data), all std-only.
//! - **L2/L1 (python/compile)**: JAX model + Pallas kernels, AOT-lowered to
//!   HLO text artifacts loaded by [`runtime`] through PJRT.
//!
//! See DESIGN.md for the system inventory and experiment index.

pub mod analysis;
pub mod calib;
pub mod cli_entry;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod linalg;
pub mod methods;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod util;
