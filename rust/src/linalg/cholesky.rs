//! Cholesky factorization and triangular utilities (f64 internals).
//!
//! The ASER whitening step needs `S` with `X Xᵀ = S Sᵀ` (paper Eq. 5, via
//! Cholesky of the calibration Gram matrix) and then `S⁻¹`. Gram matrices
//! from finite calibration sets are frequently rank-deficient, so we provide
//! a jittered factorization that escalates diagonal damping until the
//! factorization succeeds — the standard PTQ trick (GPTQ uses the same on
//! its Hessian).

use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor stored dense row-major, f64.
#[derive(Clone, Debug)]
pub struct Cholesky {
    pub n: usize,
    /// Row-major n×n; entries above the diagonal are zero.
    pub l: Vec<f64>,
    /// The damping that was actually applied to the diagonal (0 if none).
    pub jitter: f64,
}

impl Cholesky {
    /// Plain factorization of a symmetric positive-definite matrix `a`
    /// (row-major n×n). Fails on non-PD input.
    pub fn new(a: &[f64], n: usize) -> Result<Cholesky> {
        Self::with_jitter(a, n, 0.0)
    }

    fn with_jitter(a: &[f64], n: usize, jitter: f64) -> Result<Cholesky> {
        assert_eq!(a.len(), n * n);
        let mut l = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[i * n + j];
                if i == j {
                    sum += jitter;
                }
                for k in 0..j {
                    sum -= l[i * n + k] * l[j * n + k];
                }
                if i == j {
                    if sum <= 0.0 || !sum.is_finite() {
                        bail!("matrix not positive definite at pivot {i} (sum={sum})");
                    }
                    l[i * n + j] = sum.sqrt();
                } else {
                    l[i * n + j] = sum / l[j * n + j];
                }
            }
        }
        Ok(Cholesky { n, l, jitter })
    }

    /// Factorize with escalating diagonal jitter (relative to mean diagonal)
    /// until success. Mirrors GPTQ's `percdamp` practice.
    pub fn damped(a: &[f64], n: usize) -> Result<Cholesky> {
        let mean_diag = (0..n).map(|i| a[i * n + i]).sum::<f64>() / n as f64;
        let base = mean_diag.abs().max(1e-12);
        let mut rel = 0.0f64;
        for attempt in 0..12 {
            let jitter = base * rel;
            match Self::with_jitter(a, n, jitter) {
                Ok(c) => return Ok(c),
                Err(_) => {
                    rel = if attempt == 0 { 1e-8 } else { rel * 10.0 };
                }
            }
        }
        bail!("cholesky failed even with jitter {:.3e}", base * rel)
    }

    /// Solve L y = b (forward substitution).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        assert_eq!(b.len(), n);
        let mut y = vec![0f64; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l[i * n + k] * y[k];
            }
            y[i] = s / self.l[i * n + i];
        }
        y
    }

    /// Solve Lᵀ x = b (back substitution).
    pub fn solve_upper_t(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        assert_eq!(b.len(), n);
        let mut x = vec![0f64; n];
        for i in (0..n).rev() {
            let mut s = b[i];
            for k in i + 1..n {
                s -= self.l[k * n + i] * x[k];
            }
            x[i] = s / self.l[i * n + i];
        }
        x
    }

    /// Solve A x = b with A = L Lᵀ.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.solve_upper_t(&self.solve_lower(b))
    }

    /// Dense inverse of the lower-triangular factor: L⁻¹ (row-major n×n).
    /// Needed for the whitening matrices `S⁻¹` and `L_B = V_rᵀ S⁻¹`.
    pub fn inverse_lower(&self) -> Vec<f64> {
        let n = self.n;
        let mut inv = vec![0f64; n * n];
        // Column by column: L · inv[:, j] = e_j; inv is lower triangular.
        for j in 0..n {
            inv[j * n + j] = 1.0 / self.l[j * n + j];
            for i in j + 1..n {
                let mut s = 0f64;
                for k in j..i {
                    s -= self.l[i * n + k] * inv[k * n + j];
                }
                inv[i * n + j] = s / self.l[i * n + i];
            }
        }
        inv
    }

    /// log-determinant of A = L Lᵀ.
    pub fn logdet(&self) -> f64 {
        2.0 * (0..self.n).map(|i| self.l[i * self.n + i].ln()).sum::<f64>()
    }
}

/// Dense lower-triangular matvec: y = L x.
pub fn lower_matvec(l: &[f64], n: usize, x: &[f64]) -> Vec<f64> {
    let mut y = vec![0f64; n];
    for i in 0..n {
        let mut s = 0f64;
        for k in 0..=i {
            s += l[i * n + k] * x[k];
        }
        y[i] = s;
    }
    y
}

/// C = A·B for dense row-major f64 (small helper for tests/whitening).
pub fn matmul_f64(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0f64; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            for j in 0..n {
                c[i * n + j] += av * b[p * n + j];
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Random SPD matrix A = B Bᵀ + n·I.
    fn random_spd(rng: &mut Pcg64, n: usize) -> Vec<f64> {
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal() as f64).collect();
        let mut a = matmul_f64(&b, &transpose(&b, n, n), n, n, n);
        for i in 0..n {
            a[i * n + i] += n as f64;
        }
        a
    }

    fn transpose(a: &[f64], m: usize, n: usize) -> Vec<f64> {
        let mut t = vec![0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                t[j * m + i] = a[i * n + j];
            }
        }
        t
    }

    #[test]
    fn reconstructs_a() {
        let mut rng = Pcg64::seed(3);
        for n in [1, 2, 5, 17, 40] {
            let a = random_spd(&mut rng, n);
            let ch = Cholesky::new(&a, n).unwrap();
            let lt = transpose(&ch.l, n, n);
            let back = matmul_f64(&ch.l, &lt, n, n, n);
            let scale = a.iter().fold(0f64, |m, x| m.max(x.abs()));
            for (x, y) in a.iter().zip(&back) {
                assert!((x - y).abs() / scale < 1e-10, "n={n}");
            }
        }
    }

    #[test]
    fn solve_matches_direct() {
        let mut rng = Pcg64::seed(4);
        let n = 12;
        let a = random_spd(&mut rng, n);
        let ch = Cholesky::new(&a, n).unwrap();
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 - 3.0) / 2.0).collect();
        let b = matmul_f64(&a, &x_true, n, n, 1);
        let x = ch.solve(&b);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-8);
        }
    }

    #[test]
    fn inverse_lower_is_inverse() {
        let mut rng = Pcg64::seed(5);
        let n = 20;
        let a = random_spd(&mut rng, n);
        let ch = Cholesky::new(&a, n).unwrap();
        let inv = ch.inverse_lower();
        let prod = matmul_f64(&ch.l, &inv, n, n, n);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod[i * n + j] - want).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn rejects_indefinite_but_damped_succeeds() {
        // Rank-1 Gram: singular, plain Cholesky must fail, damped must work.
        let n = 4;
        let v = [1.0, 2.0, 3.0, 4.0];
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                a[i * n + j] = v[i] * v[j];
            }
        }
        assert!(Cholesky::new(&a, n).is_err());
        let ch = Cholesky::damped(&a, n).unwrap();
        assert!(ch.jitter > 0.0);
        // Still close to the original on the dominant direction.
        let y = lower_matvec(&ch.l, n, &ch.solve_lower(&v.to_vec()));
        for (yi, vi) in y.iter().zip(&v) {
            assert!((yi - vi).abs() < 1e-6);
        }
    }

    #[test]
    fn logdet_identity_zero() {
        let n = 6;
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let ch = Cholesky::new(&a, n).unwrap();
        assert!(ch.logdet().abs() < 1e-12);
    }
}
