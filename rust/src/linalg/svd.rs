//! Singular value decomposition via one-sided Jacobi rotations.
//!
//! ASER's whitening SVD (paper Eq. 6) factors `E_q S = U Σ Vᵀ` with
//! `E_q S` square (d×d, d = hidden dim). One-sided Jacobi is simple,
//! numerically robust (it computes small singular values to high relative
//! accuracy) and O(d³ · sweeps); fast enough for d ≤ 512 at calibration
//! time. Internals are f64; inputs/outputs are the f32 `Matrix` type.

use crate::tensor::Matrix;

/// Thin SVD result: `a ≈ u · diag(s) · vt` with `u`: m×k, `s`: k, `vt`: k×n,
/// k = min(m, n), singular values descending.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Matrix,
    pub s: Vec<f32>,
    pub vt: Matrix,
    pub sweeps: usize,
}

/// One-sided Jacobi SVD. Orthogonalizes the *columns* of a working copy of
/// `A` by plane rotations accumulated into V; at convergence the column
/// norms are the singular values and the normalized columns are U.
pub fn svd(a: &Matrix) -> Svd {
    let (m, n) = (a.rows, a.cols);
    // For tall-thin inputs work as-is; for wide inputs factor the transpose
    // and swap (U,V) — one-sided Jacobi wants m >= n for efficiency.
    if m < n {
        let t = svd(&a.transpose());
        return Svd { u: t.vt.transpose(), s: t.s, vt: t.u.transpose(), sweeps: t.sweeps };
    }
    // Working copy in f64, column-major for cheap column ops.
    let mut w: Vec<Vec<f64>> = (0..n).map(|j| (0..m).map(|i| a[(i, j)] as f64).collect()).collect();
    let mut v: Vec<Vec<f64>> = (0..n)
        .map(|j| (0..n).map(|i| if i == j { 1.0 } else { 0.0 }).collect())
        .collect();

    let scale = a.max_abs().max(f32::MIN_POSITIVE) as f64;
    let eps = 1e-15 * scale * scale * m as f64;
    let max_sweeps = 60;
    let mut sweeps = 0;
    for sweep in 0..max_sweeps {
        sweeps = sweep + 1;
        let mut off = 0f64;
        for p in 0..n {
            for q in p + 1..n {
                // 2x2 Gram block of columns p, q.
                let (mut app, mut aqq, mut apq) = (0f64, 0f64, 0f64);
                for i in 0..m {
                    app += w[p][i] * w[p][i];
                    aqq += w[q][i] * w[q][i];
                    apq += w[p][i] * w[q][i];
                }
                off = off.max(apq.abs());
                if apq.abs() <= eps || apq.abs() <= 1e-14 * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation annihilating apq.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let wp = w[p][i];
                    let wq = w[q][i];
                    w[p][i] = c * wp - s * wq;
                    w[q][i] = s * wp + c * wq;
                }
                for i in 0..n {
                    let vp = v[p][i];
                    let vq = v[q][i];
                    v[p][i] = c * vp - s * vq;
                    v[q][i] = s * vp + c * vq;
                }
            }
        }
        if off <= eps {
            break;
        }
    }

    // Column norms = singular values; sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = w.iter().map(|col| col.iter().map(|x| x * x).sum::<f64>().sqrt()).collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());

    let mut u = Matrix::zeros(m, n);
    let mut vt = Matrix::zeros(n, n);
    let mut s = Vec::with_capacity(n);
    for (k, &j) in order.iter().enumerate() {
        let norm = norms[j];
        s.push(norm as f32);
        if norm > 0.0 {
            for i in 0..m {
                u[(i, k)] = (w[j][i] / norm) as f32;
            }
        } else {
            // Null direction: leave U column zero (callers truncate anyway).
        }
        for i in 0..n {
            vt[(k, i)] = v[j][i] as f32;
        }
    }
    Svd { u, s, vt, sweeps }
}

impl Svd {
    /// Reconstruct the rank-r approximation U_r Σ_r V_rᵀ.
    pub fn reconstruct(&self, r: usize) -> Matrix {
        let r = r.min(self.s.len());
        let mut out = Matrix::zeros(self.u.rows, self.vt.cols);
        for k in 0..r {
            let sk = self.s[k];
            if sk == 0.0 {
                continue;
            }
            for i in 0..out.rows {
                let uik = self.u[(i, k)] * sk;
                if uik == 0.0 {
                    continue;
                }
                let orow = out.row_mut(i);
                let vrow = self.vt.row(k);
                for (o, &v) in orow.iter_mut().zip(vrow) {
                    *o += uik * v;
                }
            }
        }
        out
    }

    /// `L_A = U_r Σ_r` (m×r).
    pub fn factor_a(&self, r: usize) -> Matrix {
        let r = r.min(self.s.len());
        Matrix::from_fn(self.u.rows, r, |i, k| self.u[(i, k)] * self.s[k])
    }

    /// `V_rᵀ` (r×n).
    pub fn factor_vt(&self, r: usize) -> Matrix {
        let r = r.min(self.s.len());
        self.vt.rows_slice(0, r)
    }
}

/// Effective rank (Roy & Vetterli 2007; paper Eq. 3-4): exp of the entropy
/// of the normalized singular-value distribution.
pub fn effective_rank(s: &[f32]) -> f32 {
    let total: f64 = s.iter().map(|&x| x.max(0.0) as f64).sum();
    if total <= 0.0 {
        return 0.0;
    }
    let eps = 1e-12;
    let mut h = 0f64;
    for &x in s {
        let p = (x.max(0.0) as f64) / total + eps;
        h -= p * p.ln();
    }
    h.exp() as f32
}

/// Smallest r with cumsum(σ)/sum(σ) ≥ α — the paper's rank-selection rule
/// (Eq. 9: the largest r with ratio < α, plus one to reach the threshold).
pub fn rank_for_threshold(s: &[f32], alpha: f64) -> usize {
    let total: f64 = s.iter().map(|&x| x as f64).sum();
    if total <= 0.0 {
        return 0;
    }
    let mut acc = 0f64;
    for (i, &x) in s.iter().enumerate() {
        acc += x as f64;
        if acc / total >= alpha {
            return i + 1;
        }
    }
    s.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;
    use crate::util::rng::Pcg64;

    fn check_orthonormal_cols(m: &Matrix, k: usize, tol: f32) {
        for a in 0..k {
            for b in a..k {
                let mut dot = 0f32;
                for i in 0..m.rows {
                    dot += m[(i, a)] * m[(i, b)];
                }
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < tol, "cols {a},{b}: {dot}");
            }
        }
    }

    #[test]
    fn reconstructs_random_square() {
        let mut rng = Pcg64::seed(21);
        for n in [1, 2, 8, 33] {
            let a = Matrix::randn(&mut rng, n, n, 1.0);
            let f = svd(&a);
            let full = f.reconstruct(n);
            assert!(a.max_diff(&full) < 1e-3 * a.max_abs().max(1.0), "n={n}");
            check_orthonormal_cols(&f.u, n, 1e-4);
            check_orthonormal_cols(&f.vt.transpose(), n, 1e-4);
            // descending
            for i in 1..f.s.len() {
                assert!(f.s[i - 1] >= f.s[i] - 1e-6);
            }
        }
    }

    #[test]
    fn reconstructs_rectangular_both_ways() {
        let mut rng = Pcg64::seed(22);
        for (m, n) in [(20, 7), (7, 20), (31, 16)] {
            let a = Matrix::randn(&mut rng, m, n, 1.0);
            let f = svd(&a);
            assert_eq!(f.u.rows, m);
            assert_eq!(f.vt.cols, n);
            let full = f.reconstruct(m.min(n));
            assert!(a.max_diff(&full) < 2e-3, "({m},{n}) diff={}", a.max_diff(&full));
        }
    }

    #[test]
    fn known_singular_values() {
        // diag(3, 2, 1) → σ = 3,2,1.
        let a = Matrix::diag(&[1.0, 3.0, 2.0]);
        let f = svd(&a);
        assert!((f.s[0] - 3.0).abs() < 1e-5);
        assert!((f.s[1] - 2.0).abs() < 1e-5);
        assert!((f.s[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn low_rank_input_recovered_exactly() {
        let mut rng = Pcg64::seed(23);
        let u = Matrix::randn(&mut rng, 24, 3, 1.0);
        let v = Matrix::randn(&mut rng, 3, 24, 1.0);
        let a = matmul(&u, &v);
        let f = svd(&a);
        // rank 3: σ₄..= ~0
        assert!(f.s[3] < 1e-4 * f.s[0]);
        let r3 = f.reconstruct(3);
        assert!(a.max_diff(&r3) < 1e-3);
    }

    #[test]
    fn eckart_young_truncation_error() {
        let mut rng = Pcg64::seed(24);
        let a = Matrix::randn(&mut rng, 16, 16, 1.0);
        let f = svd(&a);
        for r in [4usize, 8, 12] {
            let ar = f.reconstruct(r);
            let err = a.sub(&ar).frob_norm();
            let want: f32 = f.s[r..].iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((err - want).abs() / want.max(1e-6) < 1e-3, "r={r} err={err} want={want}");
        }
    }

    #[test]
    fn factors_match_reconstruct() {
        let mut rng = Pcg64::seed(25);
        let a = Matrix::randn(&mut rng, 12, 12, 1.0);
        let f = svd(&a);
        let r = 5;
        let approx = matmul(&f.factor_a(r), &f.factor_vt(r));
        assert!(approx.max_diff(&f.reconstruct(r)) < 1e-4);
    }

    #[test]
    fn effective_rank_extremes() {
        // All-equal σ ⇒ eff rank = n. One dominant ⇒ close to 1.
        let flat = vec![1.0f32; 10];
        assert!((effective_rank(&flat) - 10.0).abs() < 0.01);
        let spike = {
            let mut v = vec![1e-9f32; 10];
            v[0] = 1.0;
            v
        };
        assert!(effective_rank(&spike) < 1.1);
        assert_eq!(effective_rank(&[]), 0.0);
    }

    #[test]
    fn rank_threshold_rule() {
        let s = [4.0f32, 3.0, 2.0, 1.0]; // total 10
        assert_eq!(rank_for_threshold(&s, 0.39), 1); // 0.4 >= 0.39
        assert_eq!(rank_for_threshold(&s, 0.41), 2); // need 0.7
        assert_eq!(rank_for_threshold(&s, 1.0), 4);
        assert_eq!(rank_for_threshold(&s, 0.0), 1);
        assert_eq!(rank_for_threshold(&[], 0.5), 0);
    }

    #[test]
    fn zero_matrix() {
        let a = Matrix::zeros(5, 5);
        let f = svd(&a);
        assert!(f.s.iter().all(|&x| x == 0.0));
        assert_eq!(f.reconstruct(5), a);
    }
}
