//! Dense linear algebra substrate: Cholesky factorization (with GPTQ-style
//! damping), one-sided Jacobi SVD, whitening transforms, effective rank and
//! the paper's rank-selection rule.

pub mod cholesky;
pub mod eigh;
pub mod svd;
pub mod whiten;

pub use cholesky::Cholesky;
pub use eigh::{eigh_jacobi, svd_gram};
pub use svd::{effective_rank, rank_for_threshold, svd, Svd};
pub use whiten::Whitener;
