//! Symmetric eigendecomposition (cyclic Jacobi) and the Gram-trick SVD.
//!
//! The ASER pipeline takes one SVD per linear layer; for tall error
//! matrices (e.g. fc1: 1024×256) one-sided Jacobi costs
//! O(sweeps · m · n²). The Gram trick — eigh of AᵀA (n×n) followed by
//! U = A·V·Σ⁻¹ — costs O(m·n² + sweeps·n³), a ~sweeps·m/n speedup, at the
//! price of squaring the condition number. Quantization-error spectra are
//! flat enough (σ₁/σₙ ≲ 1e3) that f64 internals keep the top-r components
//! we truncate to accurate; the §Perf log records the cross-check against
//! the one-sided reference.

use crate::tensor::Matrix;

/// Eigendecomposition of a symmetric matrix (row-major f64, n×n).
/// Returns (eigenvalues descending, eigenvectors as rows of V: V[k] is the
/// k-th eigenvector).
pub fn eigh_jacobi(a: &[f64], n: usize) -> (Vec<f64>, Vec<Vec<f64>>) {
    assert_eq!(a.len(), n * n);
    let mut m = a.to_vec();
    let mut v: Vec<Vec<f64>> = (0..n)
        .map(|i| (0..n).map(|j| if i == j { 1.0 } else { 0.0 }).collect())
        .collect();
    let scale = a.iter().fold(0f64, |acc, x| acc.max(x.abs())).max(1e-300);
    let eps = 1e-14 * scale;
    for _sweep in 0..60 {
        let mut off = 0f64;
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[p * n + q];
                off = off.max(apq.abs());
                if apq.abs() <= eps {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // Update rows/cols p and q of the symmetric matrix.
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vpk = v[p][k];
                    let vqk = v[q][k];
                    v[p][k] = c * vpk - s * vqk;
                    v[q][k] = s * vpk + c * vqk;
                }
            }
        }
        if off <= eps {
            break;
        }
    }
    let mut order: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m[i * n + i]).collect();
    order.sort_by(|&i, &j| diag[j].partial_cmp(&diag[i]).unwrap());
    let vals: Vec<f64> = order.iter().map(|&i| diag[i]).collect();
    let vecs: Vec<Vec<f64>> = order.iter().map(|&i| v[i].clone()).collect();
    (vals, vecs)
}

/// Gram-trick SVD: fast path used by the quantization pipeline.
/// Semantics match [`super::svd::svd`] (thin SVD, σ descending).
pub fn svd_gram(a: &Matrix) -> super::svd::Svd {
    let (m, n) = (a.rows, a.cols);
    if m < n {
        let t = svd_gram(&a.transpose());
        return super::svd::Svd {
            u: t.vt.transpose(),
            s: t.s,
            vt: t.u.transpose(),
            sweeps: t.sweeps,
        };
    }
    // G = AᵀA in f64.
    let g = crate::tensor::gram_cols_f64(a);
    let (vals, vecs) = eigh_jacobi(&g, n);
    let mut s = Vec::with_capacity(n);
    let mut vt = Matrix::zeros(n, n);
    for k in 0..n {
        s.push(vals[k].max(0.0).sqrt() as f32);
        for j in 0..n {
            vt[(k, j)] = vecs[k][j] as f32;
        }
    }
    // U = A V Σ⁻¹, column by column; zero for negligible σ.
    let mut u = Matrix::zeros(m, n);
    let sigma_floor = s.first().copied().unwrap_or(0.0) as f64 * 1e-7;
    for k in 0..n {
        let sk = s[k] as f64;
        if sk <= sigma_floor || sk == 0.0 {
            continue;
        }
        let inv = (1.0 / sk) as f32;
        for i in 0..m {
            let mut acc = 0f32;
            let row = a.row(i);
            let vk = vt.row(k);
            acc += crate::tensor::dot(row, vk);
            u[(i, k)] = acc * inv;
        }
    }
    super::svd::Svd { u, s, vt, sweeps: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::svd;
    use crate::util::rng::Pcg64;

    #[test]
    fn eigh_identity() {
        let n = 5;
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            a[i * n + i] = (i + 1) as f64;
        }
        let (vals, vecs) = eigh_jacobi(&a, n);
        assert!((vals[0] - 5.0).abs() < 1e-12);
        assert!((vals[4] - 1.0).abs() < 1e-12);
        // eigenvectors orthonormal
        for i in 0..n {
            for j in 0..n {
                let dot: f64 = vecs[i].iter().zip(&vecs[j]).map(|(a, b)| a * b).sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn eigh_reconstructs_symmetric() {
        let mut rng = Pcg64::seed(201);
        let n = 12;
        let b = Matrix::randn(&mut rng, n, n, 1.0);
        let g = crate::tensor::gram_cols_f64(&b);
        let (vals, vecs) = eigh_jacobi(&g, n);
        // A = Σ λ_k v_k v_kᵀ
        for i in 0..n {
            for j in 0..n {
                let mut acc = 0f64;
                for k in 0..n {
                    acc += vals[k] * vecs[k][i] * vecs[k][j];
                }
                assert!((acc - g[i * n + j]).abs() < 1e-8 * vals[0].abs().max(1.0));
            }
        }
    }

    #[test]
    fn svd_gram_matches_jacobi_on_spectra() {
        let mut rng = Pcg64::seed(202);
        for (m, n) in [(20, 20), (48, 16), (16, 48)] {
            let a = Matrix::randn(&mut rng, m, n, 1.0);
            let f1 = svd(&a);
            let f2 = svd_gram(&a);
            for k in 0..m.min(n) {
                let rel = (f1.s[k] - f2.s[k]).abs() / f1.s[0].max(1e-9);
                assert!(rel < 1e-4, "({m},{n}) σ{k}: {} vs {}", f1.s[k], f2.s[k]);
            }
            // rank-r reconstruction must match the reference reconstruction
            let r = 4.min(m.min(n));
            let r1 = f1.reconstruct(r);
            let r2 = f2.reconstruct(r);
            assert!(r1.max_diff(&r2) < 1e-3, "({m},{n})");
        }
    }

    #[test]
    fn svd_gram_handles_rank_deficient() {
        let mut rng = Pcg64::seed(203);
        let u = Matrix::randn(&mut rng, 30, 3, 1.0);
        let v = Matrix::randn(&mut rng, 3, 18, 1.0);
        let a = crate::tensor::matmul(&u, &v);
        let f = svd_gram(&a);
        assert!(f.s[3] < 1e-3 * f.s[0]);
        let r3 = f.reconstruct(3);
        assert!(a.max_diff(&r3) < 1e-2);
    }
}
