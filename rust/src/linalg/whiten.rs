//! Whitening transform for ASER's Error Reconstruction (paper Eq. 5-6).
//!
//! Given the calibration Gram matrix `G = X Xᵀ` over input channels
//! (d×d, accumulated in f64 by `calib`), compute a lower-triangular `S`
//! with `G = S Sᵀ` so that `S⁻¹ X` has identity second moment, plus `S⁻¹`
//! for building `L_B = V_rᵀ S⁻¹`.

use crate::linalg::cholesky::Cholesky;
use crate::tensor::Matrix;
use anyhow::Result;

/// The whitening pair (S, S⁻¹) as f32 matrices, plus diagnostics.
#[derive(Clone, Debug)]
pub struct Whitener {
    pub s: Matrix,
    pub s_inv: Matrix,
    /// Diagonal damping that Cholesky needed (0 for healthy Grams).
    pub jitter: f64,
}

impl Whitener {
    /// Build from a row-major f64 Gram matrix (d×d).
    pub fn from_gram(gram: &[f64], d: usize) -> Result<Whitener> {
        let ch = Cholesky::damped(gram, d)?;
        let inv = ch.inverse_lower();
        let s = Matrix::from_fn(d, d, |i, j| ch.l[i * d + j] as f32);
        let s_inv = Matrix::from_fn(d, d, |i, j| inv[i * d + j] as f32);
        Ok(Whitener { s, s_inv, jitter: ch.jitter })
    }

    /// Build directly from an activation sample matrix X (tokens×d):
    /// G = Xᵀ X scaled by 1/tokens (scaling cancels in L_A·L_B but keeps
    /// the Cholesky well-conditioned).
    pub fn from_activations(x: &Matrix) -> Result<Whitener> {
        let d = x.cols;
        let mut g = crate::tensor::gram_cols_f64(x);
        let scale = 1.0 / x.rows.max(1) as f64;
        for v in &mut g {
            *v *= scale;
        }
        Whitener::from_gram(&g, d)
    }

    pub fn dim(&self) -> usize {
        self.s.rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{gram_cols_f64, matmul};
    use crate::util::rng::Pcg64;

    #[test]
    fn whitened_activations_have_identity_gram() {
        let mut rng = Pcg64::seed(31);
        let d = 16;
        // Anisotropic activations: per-channel scales spanning 3 decades.
        let mut x = Matrix::randn(&mut rng, 400, d, 1.0);
        for c in 0..d {
            let s = 10f32.powf(rng.range_f32(-1.5, 1.5));
            for r in 0..400 {
                x[(r, c)] *= s;
            }
        }
        let w = Whitener::from_activations(&x).unwrap();
        // (S⁻¹ Xᵀ) (S⁻¹ Xᵀ)ᵀ / tokens = I   (X here is tokens×d so Xᵀ is d×tokens)
        let xt = x.transpose();
        let wx = matmul(&w.s_inv, &xt);
        let g = gram_cols_f64(&wx.transpose());
        for i in 0..d {
            for j in 0..d {
                let got = g[i * d + j] / 400.0;
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((got - want).abs() < 1e-2, "({i},{j}): {got}");
            }
        }
    }

    #[test]
    fn s_times_sinv_is_identity() {
        let mut rng = Pcg64::seed(32);
        let x = Matrix::randn(&mut rng, 100, 12, 1.0);
        let w = Whitener::from_activations(&x).unwrap();
        let prod = matmul(&w.s, &w.s_inv);
        assert!(prod.max_diff(&Matrix::eye(12)) < 1e-4);
    }

    #[test]
    fn degenerate_gram_gets_jitter() {
        // Fewer samples than channels ⇒ singular Gram.
        let mut rng = Pcg64::seed(33);
        let x = Matrix::randn(&mut rng, 4, 16, 1.0);
        let w = Whitener::from_activations(&x).unwrap();
        assert!(w.jitter > 0.0);
        assert!(w.s.is_finite());
        assert!(w.s_inv.is_finite());
    }
}
