//! A small work-stealing-free thread pool (std-only).
//!
//! The coordinator parallelizes per-layer quantization jobs and serving
//! worker loops. With no rayon/tokio in the offline dep closure we use a
//! fixed pool of `std::thread` workers over an mpsc channel, plus a
//! `scope_map` helper that applies a closure over an index range and
//! collects results in order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool. Dropping the pool joins all workers.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// `n == 0` picks the available parallelism (min 1).
    pub fn new(n: usize) -> Self {
        let n = if n == 0 {
            thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            n
        };
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let queued = Arc::clone(&queued);
            workers.push(
                thread::Builder::new()
                    .name(format!("aser-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                queued.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn pool worker"),
            );
        }
        ThreadPool { tx: Some(tx), workers, queued }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("pool worker alive");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Inline-vs-fan-out heuristic shared by the data-parallel hot paths
/// (`tensor::qgemm::auto_threads`, `tensor::attn_kernel::auto_threads`).
/// The `scope_map` workers are spawned per call (std scoped threads, no
/// persistent pool), which costs ~10µs each — more than a decode-sized
/// kernel — so jobs below the caller's `floor` stay on the calling thread
/// and larger ones use every core. Each caller calibrates `floor` to its
/// own work unit (qgemm: output elements, ~d_in MACs each; attention: raw
/// q·K MACs), so the spawn-cost logic lives in one place without
/// pretending the units are comparable.
pub fn fanout_threads(work: usize, floor: usize) -> usize {
    if work >= floor {
        thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        1
    }
}

/// Apply `f` to every index in `0..n` on `threads` scoped threads and return
/// results in index order. Panics in workers propagate. This borrows `f`'s
/// captures for the duration of the call (no 'static bound), so it is the
/// workhorse for data-parallel numeric loops.
pub fn scope_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        return (0..n).map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots_ptr = SendPtr(slots.as_mut_ptr());
    thread::scope(|s| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            let slots_ptr = &slots_ptr;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // SAFETY: each index is claimed exactly once via the atomic
                // counter, so writes to distinct slots never alias; the
                // scope guarantees the threads finish before `slots` is read.
                unsafe {
                    *slots_ptr.0.add(i) = Some(v);
                }
            });
        }
    });
    slots.into_iter().map(|x| x.expect("slot filled")).collect()
}

/// A raw pointer that asserts cross-thread shareability. Shared with the
/// attention driver (`model::gpt::Gpt::attn_layer`), which hands disjoint
/// scratch ranges to (sequence × head) work items the same way `scope_map`
/// hands out result slots: every user must guarantee disjoint writes and a
/// join before reads.
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
// SAFETY: see scope_map — disjoint index writes only.
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_map_ordered() {
        let out = scope_map(257, 8, |i| i * i);
        assert_eq!(out.len(), 257);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn scope_map_empty_and_single() {
        assert!(scope_map(0, 4, |i| i).is_empty());
        assert_eq!(scope_map(1, 4, |i| i + 7), vec![7]);
    }

    #[test]
    fn scope_map_borrows_environment() {
        let data: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let sums = scope_map(10, 4, |chunk| {
            data[chunk * 100..(chunk + 1) * 100].iter().sum::<f64>()
        });
        let total: f64 = sums.iter().sum();
        assert_eq!(total, (0..1000).sum::<usize>() as f64);
    }
}
