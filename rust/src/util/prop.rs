//! Miniature property-testing harness (proptest is not in the offline dep
//! closure). Provides seeded random-case generation with failure shrinking
//! for numeric vectors and integers — enough for the invariant suites in
//! `rust/tests/`.

use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink: usize,
    /// Hang guard: if a single case (or shrink candidate) makes no progress
    /// for this long, the run prints the property name, active case, and
    /// seed to stderr and aborts the whole process (exit 101) — a wedged
    /// fault-injection test fails fast with a reproducible report instead
    /// of hanging tier-1 until the CI timeout. `None` disables the guard.
    pub case_timeout: Option<Duration>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 128,
            seed: 0xA5E12,
            max_shrink: 200,
            case_timeout: Some(Duration::from_secs(120)),
        }
    }
}

/// Watchdog heartbeat: `check` bumps it on every case and shrink candidate;
/// the guard thread aborts the process when it stops moving. The guard is
/// disarmed on drop (normal return or a property-failure panic), so it
/// never outlives its `check` call armed.
struct Watchdog {
    done: Arc<AtomicBool>,
}

impl Watchdog {
    fn arm(name: &str, seed: u64, timeout: Duration, beat: Arc<AtomicU64>) -> Watchdog {
        let done = Arc::new(AtomicBool::new(false));
        let done2 = Arc::clone(&done);
        let name = name.to_string();
        std::thread::spawn(move || {
            let poll = timeout.min(Duration::from_millis(200)).max(Duration::from_millis(10));
            let mut last = beat.load(Ordering::Acquire);
            let mut last_change = Instant::now();
            loop {
                std::thread::sleep(poll);
                if done2.load(Ordering::Acquire) {
                    return;
                }
                let now = beat.load(Ordering::Acquire);
                if now != last {
                    last = now;
                    last_change = Instant::now();
                    continue;
                }
                if last_change.elapsed() > timeout {
                    // Re-check done right before the kill: the run may have
                    // finished while we slept.
                    if done2.load(Ordering::Acquire) {
                        return;
                    }
                    eprintln!(
                        "property '{name}' wedged: no progress for {timeout:?} \
                         (case {last}, seed {seed:#x}); aborting run"
                    );
                    std::process::exit(101);
                }
            }
        });
        Watchdog { done }
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.done.store(true, Ordering::Release);
    }
}

/// Outcome of a single case.
pub enum CaseResult {
    Pass,
    Fail(String),
}

/// Run `prop` over `cases` generated inputs. `gen` draws an input from the
/// RNG; `shrink` proposes smaller candidates for a failing input. Panics with
/// a reproducible report on failure.
pub fn check<T, G, S, P>(name: &str, cfg: &Config, mut gen: G, shrink: S, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> CaseResult,
{
    let mut rng = Pcg64::new(cfg.seed, crate::util::rng::hash_label(name));
    let beat = Arc::new(AtomicU64::new(0));
    let _watchdog =
        cfg.case_timeout.map(|t| Watchdog::arm(name, cfg.seed, t, Arc::clone(&beat)));
    for case in 0..cfg.cases {
        beat.store(case as u64, Ordering::Release);
        let input = gen(&mut rng);
        if let CaseResult::Fail(msg) = prop(&input) {
            // Shrink: greedily accept any smaller failing candidate.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut budget = cfg.max_shrink;
            'outer: loop {
                for cand in shrink(&best) {
                    if budget == 0 {
                        break 'outer;
                    }
                    budget -= 1;
                    beat.fetch_add(1, Ordering::Release);
                    if let CaseResult::Fail(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (case {case}, seed {:#x}):\n  input: {best:?}\n  {best_msg}",
                cfg.seed
            );
        }
    }
}

/// Assertion helper producing CaseResult.
pub fn ensure(cond: bool, msg: impl Fn() -> String) -> CaseResult {
    if cond {
        CaseResult::Pass
    } else {
        CaseResult::Fail(msg())
    }
}

/// Combine sub-checks: first failure wins.
pub fn all(results: Vec<CaseResult>) -> CaseResult {
    for r in results {
        if let CaseResult::Fail(m) = r {
            return CaseResult::Fail(m);
        }
    }
    CaseResult::Pass
}

// -- standard generators ---------------------------------------------------

/// Random f32 vector with mixed magnitudes (including outliers + zeros).
pub fn gen_vec_f32(rng: &mut Pcg64, max_len: usize) -> Vec<f32> {
    let len = 1 + rng.below(max_len.max(1));
    (0..len)
        .map(|_| match rng.below(10) {
            0 => 0.0,
            1 => rng.heavy_tailed(0.5, 100.0),
            _ => rng.normal(),
        })
        .collect()
}

/// Shrinker for vectors: halves, then element simplification toward 0.
pub fn shrink_vec_f32(v: &Vec<f32>) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    if v.len() > 1 {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
    }
    for i in 0..v.len().min(8) {
        if v[i] != 0.0 {
            let mut c = v.clone();
            c[i] = 0.0;
            out.push(c);
        }
    }
    out
}

/// Shrinker for sized inputs (usize): halving ladder.
pub fn shrink_usize(n: &usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut x = *n;
    while x > 1 {
        x /= 2;
        out.push(x);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let cfg = Config { cases: 50, ..Default::default() };
        check(
            "abs_nonneg",
            &cfg,
            |rng| gen_vec_f32(rng, 32),
            shrink_vec_f32,
            |v| ensure(v.iter().all(|x| x.abs() >= 0.0), || "abs < 0".into()),
        );
    }

    #[test]
    #[should_panic(expected = "property 'always_fails' failed")]
    fn failing_property_panics_with_shrunk_input() {
        let cfg = Config { cases: 5, ..Default::default() };
        check(
            "always_fails",
            &cfg,
            |rng| gen_vec_f32(rng, 64),
            shrink_vec_f32,
            |v| ensure(v.len() > 100, || format!("len {} <= 100", v.len())),
        );
    }

    #[test]
    fn watchdog_tolerates_slow_but_progressing_cases_and_disarms() {
        let cfg = Config {
            cases: 3,
            case_timeout: Some(Duration::from_millis(80)),
            ..Default::default()
        };
        check(
            "slow_but_progressing",
            &cfg,
            |rng| {
                // Each case is slower than the poll tick but faster than the
                // timeout: progress keeps the guard quiet.
                std::thread::sleep(Duration::from_millis(30));
                1 + rng.below(10)
            },
            shrink_usize,
            |_| CaseResult::Pass,
        );
        // The guard must be disarmed now: if it were still armed with the
        // heartbeat frozen, this sleep would let it kill the process (exit
        // 101), failing the whole test binary loudly.
        std::thread::sleep(Duration::from_millis(200));
    }

    #[test]
    fn shrinkers_reduce() {
        let v = vec![1.0f32; 16];
        let cands = shrink_vec_f32(&v);
        assert!(cands.iter().any(|c| c.len() < v.len()));
        assert_eq!(shrink_usize(&8), vec![4, 2, 1]);
    }
}
