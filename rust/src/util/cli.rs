//! Hand-rolled CLI argument parser (no clap in the offline dep closure).
//!
//! Supports: subcommands, `--flag`, `--key value`, `--key=value`, positional
//! args, typed accessors with defaults, and auto-generated usage text.

use std::collections::BTreeMap;

/// Declarative option spec used for usage text and validation.
#[derive(Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    pub cmd: String,
    pub kv: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`: first token without a leading `-` is the
    /// subcommand; the rest is options/positionals.
    pub fn parse(argv: &[String], flag_names: &[&str]) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.cmd = it.next().unwrap().clone();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    out.kv.insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        // treat as a bare flag even if undeclared
                        out.flags.push(body.to_string());
                    } else {
                        out.kv.insert(body.to_string(), it.next().unwrap().clone());
                    }
                } else {
                    out.flags.push(body.to_string());
                }
            } else if tok == "-h" {
                out.flags.push("help".to_string());
            } else {
                out.positional.push(tok.clone());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<u64>()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn require(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing required option --{key}"))
    }

    /// Parse a comma-separated list, e.g. `--alphas 0.015,0.03,0.05`.
    pub fn list_f64(&self, key: &str) -> anyhow::Result<Option<Vec<f64>>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => {
                let mut out = Vec::new();
                for part in v.split(',') {
                    out.push(
                        part.trim()
                            .parse::<f64>()
                            .map_err(|_| anyhow::anyhow!("--{key}: bad number '{part}'"))?,
                    );
                }
                Ok(Some(out))
            }
        }
    }
}

/// Render a usage block for a subcommand.
pub fn usage(cmd: &str, summary: &str, opts: &[OptSpec]) -> String {
    let mut out = format!("usage: repro {cmd} [options]\n\n{summary}\n\noptions:\n");
    for o in opts {
        let lhs = if o.is_flag {
            format!("  --{}", o.name)
        } else {
            format!("  --{} <v>", o.name)
        };
        let def = o.default.map(|d| format!(" (default: {d})")).unwrap_or_default();
        out.push_str(&format!("{lhs:<28}{}{def}\n", o.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_kv_flags() {
        let a = Args::parse(&argv("quantize --model A --wbits 4 --verbose --alpha=0.05"), &["verbose"])
            .unwrap();
        assert_eq!(a.cmd, "quantize");
        assert_eq!(a.get("model"), Some("A"));
        assert_eq!(a.usize_or("wbits", 8).unwrap(), 4);
        assert_eq!(a.f64_or("alpha", 0.1).unwrap(), 0.05);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn positional_and_defaults() {
        let a = Args::parse(&argv("eval path/to/run --seed 7"), &[]).unwrap();
        assert_eq!(a.positional, vec!["path/to/run"]);
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
        assert_eq!(a.str_or("missing", "d"), "d");
    }

    #[test]
    fn undeclared_trailing_flag() {
        let a = Args::parse(&argv("run --fast"), &[]).unwrap();
        assert!(a.flag("fast"));
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(&argv("t --alphas 0.1,0.2,0.3"), &[]).unwrap();
        assert_eq!(a.list_f64("alphas").unwrap().unwrap(), vec![0.1, 0.2, 0.3]);
        let bad = Args::parse(&argv("t --alphas 0.1,x"), &[]).unwrap();
        assert!(bad.list_f64("alphas").is_err());
    }

    #[test]
    fn type_errors() {
        let a = Args::parse(&argv("t --n abc"), &[]).unwrap();
        assert!(a.usize_or("n", 1).is_err());
        assert!(a.require("missing").is_err());
    }
}
