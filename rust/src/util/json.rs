//! Minimal JSON parser + writer (std-only).
//!
//! The repo runs fully offline with only the `xla` crate's vendored dep
//! closure available, which excludes serde. Configs, artifact manifests and
//! experiment reports are small, so we carry a small, strict JSON
//! implementation: UTF-8, no trailing commas, `\uXXXX` escapes (including
//! surrogate pairs), f64 numbers.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a BTreeMap so serialization is
/// deterministic (stable key order) — important for artifact diffing.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { s: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// `obj.num("lr")` style convenience with error context.
    pub fn num(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid numeric field '{key}'"))
    }
    pub fn int(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field '{key}'"))
    }
    pub fn str_field(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    // -- writer ----------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/Infinity literal; `{x}` would emit
                    // "NaN"/"inf" and corrupt the document. Null is the
                    // conventional lossy fallback.
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (k, v) in a.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        push_indent(out, indent + 1);
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !a.is_empty() {
                    out.push('\n');
                    push_indent(out, indent);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (k, (key, v)) in o.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        push_indent(out, indent + 1);
                    }
                    write_escaped(out, key);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !o.is_empty() {
                    out.push('\n');
                    push_indent(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// -- builder helpers ------------------------------------------------------

/// Build a JSON object from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
}

pub fn arr_f32(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Num(*x as f64)).collect())
}

pub fn arr_usize(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Num(*x as f64)).collect())
}

// -- parser ---------------------------------------------------------------

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.i = self.i.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = utf8_len(c);
                    if len == 1 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        for _ in 1..len {
                            self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                        }
                        let chunk = std::str::from_utf8(&self.s[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(chunk);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.s[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first < 0x80 {
        1
    } else if first < 0xE0 {
        2
    } else if first < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
        // Writer must round-trip non-ascii verbatim.
        let re = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn deep_nesting_and_empty() {
        let v = Json::parse("[[[[[]]]]]").unwrap();
        assert!(matches!(v, Json::Arr(_)));
        let o = Json::parse("{}").unwrap();
        assert_eq!(o, Json::Obj(Default::default()));
    }

    #[test]
    fn integers_written_without_fraction() {
        let v = obj(vec![("n", num(3.0))]);
        assert_eq!(v.to_string_compact(), r#"{"n":3}"#);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // Surfaced by the round-trip property test: `{x}` prints "NaN"/"inf"
        // for non-finite f64, which no JSON parser (ours included) accepts.
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let doc = obj(vec![("x", num(x))]).to_string_compact();
            assert_eq!(doc, r#"{"x":null}"#);
            assert!(Json::parse(&doc).is_ok());
        }
    }

    #[test]
    fn extreme_finite_numbers_roundtrip() {
        for x in [f64::MAX, f64::MIN, f64::MIN_POSITIVE, 5e-324, -0.0, 1e15, 2.5e-7] {
            let doc = Json::Num(x).to_string_compact();
            let back = Json::parse(&doc).unwrap();
            assert_eq!(back.as_f64(), Some(x), "{x} serialized as {doc}");
        }
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 5, "s": "hi", "b": false}"#).unwrap();
        assert_eq!(v.int("n").unwrap(), 5);
        assert_eq!(v.str_field("s").unwrap(), "hi");
        assert!(v.int("s").is_err());
        assert!(v.num("missing").is_err());
    }
}
