//! std-only substrate utilities: deterministic RNG, JSON, thread pool,
//! CLI parsing, bench statistics, tensor-file IO, and a mini property-test
//! harness. These exist because the offline build environment only vendors
//! the `xla` crate's dependency closure (no serde/clap/rayon/criterion).

pub mod cli;
pub mod io;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
