//! Deterministic pseudo-random number generation.
//!
//! The whole reproduction must be bit-deterministic across runs (corpora,
//! model init, outlier injection, calibration sampling), so we carry our own
//! PRNG instead of depending on the `rand` ecosystem. The generator is
//! PCG-XSH-RR 64/32 (O'Neill 2014) with a SplitMix64 seeding stage; it is
//! fast, has good statistical quality for simulation purposes, and supports
//! cheap independent streams keyed by a string label.

/// SplitMix64 step — used for seeding and for hashing labels into streams.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Hash an arbitrary byte string to a 64-bit stream key (FNV-1a + SplitMix).
pub fn hash_label(label: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in label.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    let mut s = h;
    splitmix64(&mut s)
}

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, user-selectable stream.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg64 {
    /// Create a generator from a seed and a stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let init = splitmix64(&mut sm);
        let inc = (stream << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = init.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent generator for a named sub-purpose.
    /// `rng.fork("weights.layer3")` is stable across runs and independent of
    /// the parent's consumption position only through the label, so forks
    /// must be taken before drawing from the parent when order matters.
    pub fn fork(&self, label: &str) -> Self {
        let mut s = self.state ^ hash_label(label);
        let seed = splitmix64(&mut s);
        Self::new(seed, hash_label(label) >> 1)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value omitted for
    /// simplicity; determinism matters more than speed here).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Heavy-tailed draw: normal mixed with a log-normal outlier component.
    /// Used to synthesize activation-like channel statistics.
    pub fn heavy_tailed(&mut self, outlier_prob: f64, outlier_scale: f32) -> f32 {
        let base = self.normal();
        if self.f64() < outlier_prob {
            let mag = (self.normal() * 0.75).exp() * outlier_scale;
            base * mag
        } else {
            base
        }
    }

    /// Sample from a Zipf distribution over [0, n) with exponent `s` using
    /// inverse-CDF over precomputed weights is O(n); for repeated sampling use
    /// [`ZipfSampler`]. This one-shot version is for tests.
    pub fn zipf_once(&mut self, n: usize, s: f64) -> usize {
        let mut total = 0.0;
        for k in 1..=n {
            total += 1.0 / (k as f64).powf(s);
        }
        let mut target = self.f64() * total;
        for k in 1..=n {
            target -= 1.0 / (k as f64).powf(s);
            if target <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Precomputed-alias Zipf sampler for corpus generation (O(1) per draw).
pub struct ZipfSampler {
    /// Alias-method tables.
    prob: Vec<f64>,
    alias: Vec<usize>,
}

impl ZipfSampler {
    pub fn new(n: usize, s: f64) -> Self {
        let w: Vec<f64> = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).collect();
        Self::from_weights(&w)
    }

    /// Build an alias table (Walker/Vose) from arbitrary non-negative weights.
    pub fn from_weights(w: &[f64]) -> Self {
        let n = w.len();
        assert!(n > 0);
        let total: f64 = w.iter().sum();
        assert!(total > 0.0);
        let mut prob: Vec<f64> = w.iter().map(|x| x * n as f64 / total).collect();
        let mut alias = vec![0usize; n];
        let mut small: Vec<usize> = Vec::new();
        let mut large: Vec<usize> = Vec::new();
        for (i, p) in prob.iter().enumerate() {
            if *p < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        let mut p = prob.clone();
        while let (Some(s_i), Some(l_i)) = (small.pop(), large.pop()) {
            prob[s_i] = p[s_i];
            alias[s_i] = l_i;
            p[l_i] = p[l_i] + p[s_i] - 1.0;
            if p[l_i] < 1.0 {
                small.push(l_i);
            } else {
                large.push(l_i);
            }
        }
        for i in large {
            prob[i] = 1.0;
        }
        for i in small {
            prob[i] = 1.0;
        }
        ZipfSampler { prob, alias }
    }

    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let n = self.prob.len();
        let i = rng.below(n);
        if rng.f64() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }

    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_constructions() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_streams_diverge() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn fork_is_stable() {
        let root = Pcg64::seed(1);
        let mut f1 = root.fork("corpus");
        let mut f2 = root.fork("corpus");
        assert_eq!(f1.next_u64(), f2.next_u64());
        let mut f3 = root.fork("weights");
        assert_ne!(f1.next_u64(), f3.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::seed(3);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut rng = Pcg64::seed(9);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[rng.below(5)] += 1;
        }
        for c in counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.02, "frac={frac}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed(17);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed(5);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn choose_distinct() {
        let mut rng = Pcg64::seed(5);
        let picks = rng.choose(50, 10);
        let mut dedup = picks.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 10);
        assert!(picks.iter().all(|&i| i < 50));
    }

    #[test]
    fn zipf_alias_matches_rank_ordering() {
        let z = ZipfSampler::new(64, 1.1);
        let mut rng = Pcg64::seed(11);
        let mut counts = vec![0usize; 64];
        for _ in 0..200_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // Rank 0 should dominate rank 10 which should dominate rank 40.
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[40]);
    }

    #[test]
    fn heavy_tailed_has_outliers() {
        let mut rng = Pcg64::seed(23);
        let xs: Vec<f32> = (0..50_000).map(|_| rng.heavy_tailed(0.01, 30.0)).collect();
        let max = xs.iter().fold(0f32, |m, x| m.max(x.abs()));
        // Pure N(0,1) max over 50k draws is ~4.5; outlier mixture must exceed.
        assert!(max > 10.0, "max={max}");
    }
}
