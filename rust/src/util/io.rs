//! Binary tensor serialization shared with the python build path.
//!
//! Format ("ATNS" v1, little-endian):
//!   magic   4 bytes  b"ATNS"
//!   version u32      1
//!   ntens   u32
//!   repeated per tensor:
//!     name_len u32, name utf-8 bytes
//!     ndim u32, dims u64 × ndim
//!     dtype u8 (0 = f32, 1 = i8, 2 = u8/packed-int4, 3 = i32)
//!     payload bytes (row-major)
//!
//! `python/compile/export.py` writes the same layout for pretrained weights.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

pub const MAGIC: &[u8; 4] = b"ATNS";

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32 = 0,
    I8 = 1,
    U8 = 2,
    I32 = 3,
}

impl DType {
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 | DType::U8 => 1,
        }
    }
    fn from_u8(x: u8) -> Result<Self> {
        Ok(match x {
            0 => DType::F32,
            1 => DType::I8,
            2 => DType::U8,
            3 => DType::I32,
            _ => bail!("unknown dtype tag {x}"),
        })
    }
}

/// A named tensor blob with shape; payload is raw little-endian bytes.
#[derive(Clone, Debug)]
pub struct RawTensor {
    pub dims: Vec<usize>,
    pub dtype: DType,
    pub bytes: Vec<u8>,
}

impl RawTensor {
    pub fn from_f32(dims: Vec<usize>, data: &[f32]) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        RawTensor { dims, dtype: DType::F32, bytes }
    }

    pub fn from_u8(dims: Vec<usize>, data: Vec<u8>) -> Self {
        RawTensor { dims, dtype: DType::U8, bytes: data }
    }

    pub fn to_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("tensor is {:?}, not f32", self.dtype);
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Ordered collection of named tensors.
#[derive(Default, Debug)]
pub struct TensorFile {
    pub tensors: BTreeMap<String, RawTensor>,
}

impl TensorFile {
    pub fn insert_f32(&mut self, name: &str, dims: Vec<usize>, data: &[f32]) {
        self.tensors.insert(name.to_string(), RawTensor::from_f32(dims, data));
    }

    pub fn get(&self, name: &str) -> Result<&RawTensor> {
        self.tensors.get(name).with_context(|| format!("tensor '{name}' not in file"))
    }

    pub fn get_f32(&self, name: &str) -> Result<(Vec<usize>, Vec<f32>)> {
        let t = self.get(name)?;
        Ok((t.dims.clone(), t.to_f32()?))
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&1u32.to_le_bytes())?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&(t.dims.len() as u32).to_le_bytes())?;
            for d in &t.dims {
                w.write_all(&(*d as u64).to_le_bytes())?;
            }
            w.write_all(&[t.dtype as u8])?;
            let expect = t.numel() * t.dtype.size();
            if t.bytes.len() != expect {
                bail!("tensor '{name}': payload {} != dims*dtype {expect}", t.bytes.len());
            }
            w.write_all(&t.bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<TensorFile> {
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: bad magic", path.display());
        }
        let version = read_u32(&mut r)?;
        if version != 1 {
            bail!("unsupported ATNS version {version}");
        }
        let n = read_u32(&mut r)? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..n {
            let name_len = read_u32(&mut r)? as usize;
            let mut name_bytes = vec![0u8; name_len];
            r.read_exact(&mut name_bytes)?;
            let name = String::from_utf8(name_bytes).context("tensor name utf-8")?;
            let ndim = read_u32(&mut r)? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let mut b = [0u8; 8];
                r.read_exact(&mut b)?;
                dims.push(u64::from_le_bytes(b) as usize);
            }
            let mut tag = [0u8; 1];
            r.read_exact(&mut tag)?;
            let dtype = DType::from_u8(tag[0])?;
            let nbytes = dims.iter().product::<usize>() * dtype.size();
            let mut bytes = vec![0u8; nbytes];
            r.read_exact(&mut bytes)?;
            tensors.insert(name, RawTensor { dims, dtype, bytes });
        }
        Ok(TensorFile { tensors })
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("aser_io_test");
        let path = dir.join("t.atns");
        let mut tf = TensorFile::default();
        tf.insert_f32("w", vec![2, 3], &[1.0, -2.0, 3.5, 0.0, 5.0, -6.25]);
        tf.tensors.insert("packed".into(), RawTensor::from_u8(vec![4], vec![1, 2, 3, 255]));
        tf.save(&path).unwrap();
        let back = TensorFile::load(&path).unwrap();
        let (dims, data) = back.get_f32("w").unwrap();
        assert_eq!(dims, vec![2, 3]);
        assert_eq!(data, vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.25]);
        assert_eq!(back.get("packed").unwrap().bytes, vec![1, 2, 3, 255]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_tensor_is_error() {
        let tf = TensorFile::default();
        assert!(tf.get("nope").is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("aser_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.atns");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(TensorFile::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_dtype_access() {
        let t = RawTensor::from_u8(vec![2], vec![0, 1]);
        assert!(t.to_f32().is_err());
    }
}
