//! Timing + summary statistics for the bench harness.
//!
//! criterion is not in the offline dep closure, so the `cargo bench`
//! binaries use this module: warmup, repeated timed runs, and robust summary
//! stats (median, MAD, percentiles, mean±std, throughput).

use std::time::{Duration, Instant};

/// Summary of a set of duration samples.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Summary {
    pub fn from_durations(samples: &[Duration]) -> Summary {
        assert!(!samples.is_empty());
        let mut ns: Vec<f64> = samples.iter().map(|d| d.as_nanos() as f64).collect();
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean_ns: mean,
            std_ns: var.sqrt(),
            median_ns: percentile_sorted(&ns, 50.0),
            p10_ns: percentile_sorted(&ns, 10.0),
            p90_ns: percentile_sorted(&ns, 90.0),
            min_ns: ns[0],
            max_ns: ns[n - 1],
        }
    }

    /// Human-readable one-liner: `median 1.23ms  (p10 1.1ms, p90 1.4ms, n=30)`.
    pub fn line(&self) -> String {
        format!(
            "median {}  mean {} ± {}  (p10 {}, p90 {}, n={})",
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
            self.n
        )
    }

    /// items/sec given items processed per sample run.
    pub fn throughput(&self, items_per_run: f64) -> f64 {
        items_per_run / (self.median_ns / 1e9)
    }
}

/// Percentile of an ascending-sorted slice (linear interpolation).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Run `f` with warmup then timed iterations; adaptively picks the iteration
/// count so total timed work is ~`budget`.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> Summary {
    // Warmup + pilot measurement.
    let t0 = Instant::now();
    f();
    let pilot = t0.elapsed().max(Duration::from_nanos(100));
    let target_samples = 30usize;
    let per_sample = budget.as_secs_f64() / target_samples as f64;
    let iters_per_sample = (per_sample / pilot.as_secs_f64()).max(1.0).min(1e6) as usize;
    let mut samples = Vec::with_capacity(target_samples);
    for _ in 0..target_samples {
        let t = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        samples.push(t.elapsed() / iters_per_sample as u32);
    }
    let s = Summary::from_durations(&samples);
    println!("bench {name:<44} {}", s.line());
    s
}

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Simple wall-clock scope timer for pipeline phase logging.
pub struct ScopeTimer {
    label: String,
    start: Instant,
    quiet: bool,
}

impl ScopeTimer {
    pub fn new(label: &str) -> Self {
        ScopeTimer { label: label.to_string(), start: Instant::now(), quiet: false }
    }
    pub fn quiet(label: &str) -> Self {
        ScopeTimer { label: label.to_string(), start: Instant::now(), quiet: true }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

impl Drop for ScopeTimer {
    fn drop(&mut self) {
        if !self.quiet {
            eprintln!("[time] {}: {}", self.label, fmt_ns(self.start.elapsed().as_nanos() as f64));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant_samples() {
        let s = Summary::from_durations(&[Duration::from_micros(10); 8]);
        assert_eq!(s.median_ns, 10_000.0);
        assert_eq!(s.std_ns, 0.0);
        assert_eq!(s.min_ns, s.max_ns);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 4.0);
        assert!((percentile_sorted(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }

    #[test]
    fn bench_runs() {
        let mut acc = 0u64;
        let s = bench("noop", Duration::from_millis(20), || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(s.n > 0);
        assert!(s.median_ns >= 0.0);
    }

    #[test]
    fn throughput_positive() {
        let s = Summary::from_durations(&[Duration::from_millis(1); 4]);
        let t = s.throughput(1000.0);
        assert!((t - 1_000_000.0).abs() / 1_000_000.0 < 0.01);
    }
}
