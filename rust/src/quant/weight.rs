//! Per-channel symmetric weight quantization + packed storage.
//!
//! `QuantizedWeight` is the storage format the serving path consumes:
//! int4/int8 codes packed 2-per-byte (for ≤4 bits) with one f32 scale per
//! output channel. `fake_quant_*` helpers produce the dequantized f32 view
//! used by the PTQ methods when computing quantization errors.

use super::spec::{clamp_q, rtn, BitWidth};
use crate::tensor::Matrix;

/// Quantized weight matrix: codes are stored as i8 (unpacked) plus an
/// optionally packed nibble buffer for 4-bit storage accounting.
#[derive(Clone, Debug)]
pub struct QuantizedWeight {
    pub rows: usize,
    pub cols: usize,
    pub bits: u8,
    /// Integer codes, row-major, one i8 per element (sign-extended).
    pub codes: Vec<i8>,
    /// Per-output-channel (row) scales.
    pub scales: Vec<f32>,
}

impl QuantizedWeight {
    /// Per-channel symmetric RTN quantization of `w` (out×in).
    pub fn quantize(w: &Matrix, bits: u8) -> QuantizedWeight {
        let qmax = BitWidth(bits).qmax();
        let mut codes = vec![0i8; w.rows * w.cols];
        let mut scales = vec![0f32; w.rows];
        for r in 0..w.rows {
            let row = w.row(r);
            let amax = row.iter().fold(0f32, |m, x| m.max(x.abs()));
            let scale = if amax > 0.0 { amax / qmax } else { 1.0 };
            scales[r] = scale;
            let inv = 1.0 / scale;
            let dst = &mut codes[r * w.cols..(r + 1) * w.cols];
            for (d, &x) in dst.iter_mut().zip(row) {
                *d = clamp_q(rtn(x * inv), qmax) as i8;
            }
        }
        QuantizedWeight { rows: w.rows, cols: w.cols, bits, codes, scales }
    }

    /// Quantize with externally chosen per-row scales (used by grid-search
    /// methods like AWQ that tune the clipping range).
    pub fn quantize_with_scales(w: &Matrix, bits: u8, scales: &[f32]) -> QuantizedWeight {
        assert_eq!(scales.len(), w.rows);
        let qmax = BitWidth(bits).qmax();
        let mut codes = vec![0i8; w.rows * w.cols];
        for r in 0..w.rows {
            let scale = if scales[r] > 0.0 { scales[r] } else { 1.0 };
            let inv = 1.0 / scale;
            let dst = &mut codes[r * w.cols..(r + 1) * w.cols];
            for (d, &x) in dst.iter_mut().zip(w.row(r)) {
                *d = clamp_q(rtn(x * inv), qmax) as i8;
            }
        }
        QuantizedWeight { rows: w.rows, cols: w.cols, bits, codes, scales: scales.to_vec() }
    }

    /// Dequantize back to f32.
    pub fn dequantize(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let s = self.scales[r];
            let src = &self.codes[r * self.cols..(r + 1) * self.cols];
            for (o, &c) in out.row_mut(r).iter_mut().zip(src) {
                *o = c as f32 * s;
            }
        }
        out
    }

    /// Pack 4-bit codes two per byte (low nibble first). Errors if bits > 4.
    pub fn pack_nibbles(&self) -> anyhow::Result<Vec<u8>> {
        if self.bits > 4 {
            anyhow::bail!("cannot nibble-pack {}-bit codes", self.bits);
        }
        Ok(pack_int4(&self.codes))
    }

    /// Storage bytes for this representation (packed if ≤4 bits).
    pub fn storage_bytes(&self) -> usize {
        let code_bytes = if self.bits <= 4 {
            self.codes.len().div_ceil(2)
        } else {
            self.codes.len()
        };
        code_bytes + self.scales.len() * 4
    }
}

/// Pack i8 codes in [-8, 7] two-per-byte, low nibble first.
pub fn pack_int4(codes: &[i8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(codes.len().div_ceil(2));
    for pair in codes.chunks(2) {
        let lo = (pair[0] as u8) & 0x0F;
        let hi = if pair.len() > 1 { (pair[1] as u8) & 0x0F } else { 0 };
        out.push(lo | (hi << 4));
    }
    out
}

/// Unpack nibble-packed int4 codes (sign-extended), producing `n` values.
pub fn unpack_int4(packed: &[u8], n: usize) -> Vec<i8> {
    let mut out = Vec::with_capacity(n);
    for (i, &b) in packed.iter().enumerate() {
        let lo = sign_extend_4(b & 0x0F);
        out.push(lo);
        if 2 * i + 1 < n {
            out.push(sign_extend_4(b >> 4));
        }
        if out.len() >= n {
            break;
        }
    }
    out.truncate(n);
    out
}

#[inline]
pub fn sign_extend_4(nib: u8) -> i8 {
    ((nib << 4) as i8) >> 4
}

/// Fake-quantize a weight matrix per-channel (round-trip through the grid)
/// — the canonical `Q(W)` in the paper's equations.
pub fn fake_quant_weight(w: &Matrix, bits: u8) -> Matrix {
    QuantizedWeight::quantize(w, bits).dequantize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let mut rng = Pcg64::seed(41);
        for bits in [2u8, 3, 4, 6, 8] {
            let w = Matrix::randn(&mut rng, 10, 32, 1.0);
            let q = QuantizedWeight::quantize(&w, bits);
            let back = q.dequantize();
            for r in 0..w.rows {
                let step = q.scales[r];
                for c in 0..w.cols {
                    let err = (w[(r, c)] - back[(r, c)]).abs();
                    assert!(err <= 0.5 * step + 1e-6, "bits={bits} err={err} step={step}");
                }
            }
        }
    }

    #[test]
    fn codes_within_grid() {
        let mut rng = Pcg64::seed(42);
        let w = Matrix::randn(&mut rng, 8, 16, 3.0);
        for bits in [2u8, 4, 8] {
            let q = QuantizedWeight::quantize(&w, bits);
            let qmax = BitWidth(bits).qmax() as i8;
            assert!(q.codes.iter().all(|&c| -qmax <= c && c <= qmax));
        }
    }

    #[test]
    fn zero_row_safe() {
        let mut w = Matrix::zeros(2, 4);
        w[(1, 0)] = 1.0;
        let q = QuantizedWeight::quantize(&w, 4);
        let back = q.dequantize();
        assert!(back.row(0).iter().all(|&x| x == 0.0));
        assert!((back[(1, 0)] - 1.0).abs() < 0.1);
    }

    #[test]
    fn int4_pack_unpack_roundtrip() {
        let codes: Vec<i8> = vec![-8, -1, 0, 1, 7, 3, -5]; // odd length
        let packed = pack_int4(&codes);
        assert_eq!(packed.len(), 4);
        let back = unpack_int4(&packed, codes.len());
        assert_eq!(back, codes);
    }

    #[test]
    fn sign_extension() {
        assert_eq!(sign_extend_4(0x0F), -1);
        assert_eq!(sign_extend_4(0x08), -8);
        assert_eq!(sign_extend_4(0x07), 7);
        assert_eq!(sign_extend_4(0x00), 0);
    }

    #[test]
    fn storage_accounting() {
        let mut rng = Pcg64::seed(43);
        let w = Matrix::randn(&mut rng, 4, 10, 1.0);
        let q4 = QuantizedWeight::quantize(&w, 4);
        assert_eq!(q4.storage_bytes(), 20 + 16); // 40 codes/2 + 4 scales*4
        let q8 = QuantizedWeight::quantize(&w, 8);
        assert_eq!(q8.storage_bytes(), 40 + 16);
        assert!(q8.pack_nibbles().is_err());
        assert_eq!(q4.pack_nibbles().unwrap().len(), 20);
    }

    #[test]
    fn external_scales_respected() {
        let w = Matrix::from_vec(1, 2, vec![1.0, -2.0]);
        let q = QuantizedWeight::quantize_with_scales(&w, 4, &[0.5]);
        // 1.0/0.5 = 2, -2.0/0.5 = -4
        assert_eq!(q.codes, vec![2, -4]);
    }
}
