//! Quantization primitives: bit-grid specs, per-channel weight quantization
//! with int4 packing, and per-token activation quantization.

pub mod act;
pub mod spec;
pub mod weight;

pub use act::{
    fake_quant_acts, fake_quant_vec, quantize_tile, quantize_token, quantize_token_into,
    QuantizedToken,
};
pub use spec::{BitWidth, Precision, FP};
pub use weight::{fake_quant_weight, pack_int4, unpack_int4, QuantizedWeight};
