//! Per-token activation quantization.
//!
//! Activations are quantized **per token** (per row of the activation
//! matrix) to symmetric int-`bits`. The serving hot path quantizes on the
//! fly; PTQ methods use [`fake_quant_acts`] when measuring the integral
//! error `‖WX − W_q X_q‖_F`.

use super::spec::{clamp_q, rtn, BitWidth, FP};
use crate::tensor::Matrix;

/// One token-row quantized: int codes + scale.
#[derive(Clone, Debug)]
pub struct QuantizedToken {
    pub codes: Vec<i8>,
    pub scale: f32,
}

/// Quantize a single token activation vector.
pub fn quantize_token(x: &[f32], bits: u8) -> QuantizedToken {
    let mut codes = vec![0i8; x.len()];
    let scale = quantize_token_into(x, bits, &mut codes);
    QuantizedToken { codes, scale }
}

/// Quantize one contiguous f32 slice into caller-provided int codes,
/// returning the symmetric scale — the single source of truth for
/// slice-granular quantization semantics, shared by the GEMM activation
/// path (per token row, via [`quantize_token_into`]) and the KV-cache write
/// path (per head-row tile, `coordinator::kvpool` / `Gpt::attn_layer`).
///
/// Non-finite lanes: `amax` is NaN-immune (`f32::max` returns the other
/// operand when one side is NaN), and the saturating float→int cast in
/// `rtn`/`clamp_q` sends NaN to code 0 — so a NaN lane silently contributes
/// nothing to the dot products downstream while the rest of the slice
/// quantizes normally (pinned by `nan_lane_is_contained`). An ∞ lane does
/// poison the scale (amax = ∞ ⇒ every code rounds to 0); callers feeding
/// untrusted fp inputs should pre-filter. The returned codes are always in
/// `[-qmax, qmax]` with `qmax ≤ 127` — never −128, which the SIMD sign/abs
/// kernels in `tensor::qgemm_kernel` and `tensor::attn_kernel` rely on.
pub fn quantize_tile(x: &[f32], bits: u8, codes: &mut [i8]) -> f32 {
    debug_assert_eq!(x.len(), codes.len());
    let qmax = BitWidth(bits).qmax();
    let amax = x.iter().fold(0f32, |m, v| m.max(v.abs()));
    let scale = if amax > 0.0 { amax / qmax } else { 1.0 };
    let inv = 1.0 / scale;
    for (c, &v) in codes.iter_mut().zip(x) {
        *c = clamp_q(rtn(v * inv), qmax) as i8;
    }
    scale
}

/// Quantize a token into caller-provided storage, returning the scale — the
/// no-allocation variant the batched serving path (`tensor::qgemm`) uses for
/// its arena. Delegates to [`quantize_tile`] (a token row IS a tile), so the
/// token, batch, and KV-cache paths stay bitwise identical by construction;
/// see `quantize_tile` for the non-finite-lane semantics.
pub fn quantize_token_into(x: &[f32], bits: u8, codes: &mut [i8]) -> f32 {
    quantize_tile(x, bits, codes)
}

impl QuantizedToken {
    pub fn dequantize(&self) -> Vec<f32> {
        self.codes.iter().map(|&c| c as f32 * self.scale).collect()
    }
}

/// Fake-quantize every row of an activation matrix (tokens × d).
/// `bits == FP(16)` returns the input unchanged.
pub fn fake_quant_acts(x: &Matrix, bits: u8) -> Matrix {
    if bits == FP {
        return x.clone();
    }
    let qmax = BitWidth(bits).qmax();
    let mut out = x.clone();
    for r in 0..out.rows {
        let row = out.row_mut(r);
        let amax = row.iter().fold(0f32, |m, v| m.max(v.abs()));
        if amax == 0.0 {
            continue;
        }
        let scale = amax / qmax;
        let inv = 1.0 / scale;
        for v in row.iter_mut() {
            *v = clamp_q(rtn(*v * inv), qmax) * scale;
        }
    }
    out
}

/// In-place fake quant of a single vector; returns the scale used.
pub fn fake_quant_vec(x: &mut [f32], bits: u8) -> f32 {
    if bits == FP {
        return 1.0;
    }
    let qmax = BitWidth(bits).qmax();
    let amax = x.iter().fold(0f32, |m, v| m.max(v.abs()));
    if amax == 0.0 {
        return 1.0;
    }
    let scale = amax / qmax;
    let inv = 1.0 / scale;
    for v in x.iter_mut() {
        *v = clamp_q(rtn(*v * inv), qmax) * scale;
    }
    scale
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn token_roundtrip_bound() {
        let mut rng = Pcg64::seed(51);
        for bits in [4u8, 6, 8] {
            let x: Vec<f32> = (0..64).map(|_| rng.heavy_tailed(0.05, 20.0)).collect();
            let q = quantize_token(&x, bits);
            let back = q.dequantize();
            for (a, b) in x.iter().zip(&back) {
                assert!((a - b).abs() <= 0.5 * q.scale + 1e-6);
            }
        }
    }

    #[test]
    fn nan_lane_is_contained() {
        // Pins the documented non-finite semantics: a NaN lane does not
        // perturb amax (f32::max ignores NaN), quantizes to code 0, and
        // every other lane gets exactly the codes of the NaN-free token.
        let x = [1.0f32, f32::NAN, -2.0, 0.5];
        let mut codes = [0i8; 4];
        let scale = quantize_token_into(&x, 8, &mut codes);
        let clean = [1.0f32, 0.0, -2.0, 0.5];
        let mut clean_codes = [0i8; 4];
        let clean_scale = quantize_token_into(&clean, 8, &mut clean_codes);
        assert_eq!(scale, clean_scale, "NaN perturbed the token scale");
        assert_eq!(codes, clean_codes);
        assert_eq!(codes[1], 0, "NaN lane must quantize to 0");
        // The grid never emits -128 (the SIMD sign/abs kernels rely on it).
        let neg = [-1e30f32, 1.0];
        let mut neg_codes = [0i8; 2];
        quantize_token_into(&neg, 8, &mut neg_codes);
        assert_eq!(neg_codes[0], -127);
    }

    #[test]
    fn tile_and_token_paths_are_the_same_quantizer() {
        // quantize_tile is the shared slice-granular helper; the token path
        // must stay a pure delegate (bitwise-identical codes and scale), and
        // the documented NaN semantics must hold for the tile entry too.
        let mut rng = Pcg64::seed(56);
        for bits in [4u8, 8] {
            let x: Vec<f32> = (0..29).map(|_| rng.heavy_tailed(0.1, 10.0)).collect();
            let mut tile_codes = vec![0i8; x.len()];
            let mut tok_codes = vec![0i8; x.len()];
            let ts = quantize_tile(&x, bits, &mut tile_codes);
            let ks = quantize_token_into(&x, bits, &mut tok_codes);
            assert_eq!(ts, ks);
            assert_eq!(tile_codes, tok_codes);
        }
        // NaN lane: scale unperturbed, codes identical to the NaN-free tile,
        // NaN lane itself → code 0 (the KV write path relies on this — a
        // poisoned cache row must not poison the whole head tile).
        let x = [2.0f32, f32::NAN, -0.5];
        let clean = [2.0f32, 0.0, -0.5];
        let (mut c_x, mut c_clean) = ([0i8; 3], [0i8; 3]);
        let s_x = quantize_tile(&x, 8, &mut c_x);
        let s_clean = quantize_tile(&clean, 8, &mut c_clean);
        assert_eq!(s_x, s_clean, "NaN perturbed the tile scale");
        assert_eq!(c_x, c_clean);
        assert_eq!(c_x[1], 0, "NaN lane must quantize to 0");
        // Codes never reach -128 (SIMD sign/abs kernels rely on it).
        let neg = [-3.0f32, 1.0];
        let mut c_neg = [0i8; 2];
        quantize_tile(&neg, 8, &mut c_neg);
        assert_eq!(c_neg[0], -127);
    }

    #[test]
    fn outlier_token_inflates_everyone_elses_error() {
        // The core motivation for smoothing: one outlier channel forces a
        // large scale, coarsening all other channels in that token.
        let mut x = vec![0.5f32; 32];
        x[7] = 100.0;
        let q = quantize_token(&x, 8);
        let back = q.dequantize();
        // relative error of the small entries is large
        let rel = ((back[0] - 0.5) / 0.5).abs();
        assert!(q.scale > 0.5, "scale={}", q.scale);
        assert!(rel > 0.1, "rel={rel}");
    }

    #[test]
    fn fp16_passthrough() {
        let mut rng = Pcg64::seed(52);
        let x = Matrix::randn(&mut rng, 5, 8, 1.0);
        assert_eq!(fake_quant_acts(&x, FP), x);
    }

    #[test]
    fn matrix_and_vec_paths_agree() {
        let mut rng = Pcg64::seed(53);
        let x = Matrix::randn(&mut rng, 6, 16, 2.0);
        let m = fake_quant_acts(&x, 6);
        for r in 0..x.rows {
            let mut v = x.row(r).to_vec();
            fake_quant_vec(&mut v, 6);
            assert_eq!(m.row(r), &v[..], "row {r}");
        }
    }

    #[test]
    fn into_and_alloc_paths_agree() {
        let mut rng = Pcg64::seed(55);
        let x: Vec<f32> = (0..37).map(|_| rng.heavy_tailed(0.1, 15.0)).collect();
        for bits in [4u8, 6, 8] {
            let q = quantize_token(&x, bits);
            let mut codes = vec![0i8; x.len()];
            let scale = quantize_token_into(&x, bits, &mut codes);
            assert_eq!(scale, q.scale);
            assert_eq!(codes, q.codes);
        }
    }

    #[test]
    fn zero_vector_untouched() {
        let mut v = vec![0f32; 8];
        let s = fake_quant_vec(&mut v, 8);
        assert_eq!(s, 1.0);
        assert!(v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn lower_bits_higher_error() {
        let mut rng = Pcg64::seed(54);
        let x = Matrix::randn(&mut rng, 20, 64, 1.0);
        let err = |bits| fake_quant_acts(&x, bits).sub(&x).frob_norm();
        assert!(err(4) > err(6));
        assert!(err(6) > err(8));
    }
}
