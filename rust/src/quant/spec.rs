//! Quantization specifications shared by all PTQ methods.
//!
//! Conventions (matching the paper):
//! - weights `W` are (out_features × in_features); `y = W x`.
//! - weight quantization is **per-channel** = per output row, symmetric.
//! - activation quantization is **per-token** = per activation row, symmetric.
//! - "WxAy" means x-bit weights, y-bit activations; A16 disables activation
//!   quantization.

use std::fmt;

/// Integer grid for `bits`-bit symmetric quantization: [-qmax, qmax].
/// Uses the symmetric-around-zero grid (e.g. int8 → ±127) as SmoothQuant,
/// AWQ and friends do.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BitWidth(pub u8);

impl BitWidth {
    pub fn qmax(self) -> f32 {
        ((1i32 << (self.0 - 1)) - 1) as f32
    }
    pub fn levels(self) -> usize {
        1usize << self.0
    }
}

/// Full precision sentinel for "A16" style configs.
pub const FP: u8 = 16;

/// A weight/activation precision pair, e.g. W4A8.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Precision {
    pub wbits: u8,
    pub abits: u8,
}

impl Precision {
    pub fn new(wbits: u8, abits: u8) -> Self {
        assert!((2..=8).contains(&wbits), "wbits {wbits} out of range");
        assert!((2..=8).contains(&abits) || abits == FP, "abits {abits} out of range");
        Precision { wbits, abits }
    }
    pub fn w4a8() -> Self {
        Precision::new(4, 8)
    }
    pub fn w4a6() -> Self {
        Precision::new(4, 6)
    }
    pub fn w4a16() -> Self {
        Precision::new(4, FP)
    }
    pub fn quantize_acts(&self) -> bool {
        self.abits != FP
    }
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        // formats: "w4a8", "W4A8", "4:8"
        let lower = s.to_ascii_lowercase();
        let (w, a) = if let Some(rest) = lower.strip_prefix('w') {
            let mut parts = rest.splitn(2, 'a');
            let w = parts.next().unwrap_or("");
            let a = parts.next().unwrap_or("16");
            (w.to_string(), a.to_string())
        } else if lower.contains(':') {
            let mut parts = lower.splitn(2, ':');
            (parts.next().unwrap().to_string(), parts.next().unwrap().to_string())
        } else {
            anyhow::bail!("cannot parse precision '{s}' (use w4a8)");
        };
        let wbits: u8 = w.parse().map_err(|_| anyhow::anyhow!("bad wbits in '{s}'"))?;
        let abits: u8 = a.parse().map_err(|_| anyhow::anyhow!("bad abits in '{s}'"))?;
        Ok(Precision::new(wbits, abits))
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.abits == FP {
            write!(f, "W{}A16", self.wbits)
        } else {
            write!(f, "W{}A{}", self.wbits, self.abits)
        }
    }
}

/// Round-to-nearest-even free function used everywhere; ties away from zero
/// (matches `f32::round`, the convention in the reference int-quant stacks).
#[inline]
pub fn rtn(x: f32) -> f32 {
    x.round()
}

/// Clamp to the symmetric grid.
#[inline]
pub fn clamp_q(x: f32, qmax: f32) -> f32 {
    x.clamp(-qmax, qmax)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_values() {
        assert_eq!(BitWidth(8).qmax(), 127.0);
        assert_eq!(BitWidth(4).qmax(), 7.0);
        assert_eq!(BitWidth(6).qmax(), 31.0);
        assert_eq!(BitWidth(2).qmax(), 1.0);
        assert_eq!(BitWidth(4).levels(), 16);
    }

    #[test]
    fn precision_parse_display() {
        let p = Precision::parse("W4A8").unwrap();
        assert_eq!(p, Precision::w4a8());
        assert_eq!(p.to_string(), "W4A8");
        assert_eq!(Precision::parse("w4a16").unwrap(), Precision::w4a16());
        assert_eq!(Precision::parse("4:6").unwrap(), Precision::w4a6());
        assert!(Precision::parse("junk").is_err());
        assert!(!Precision::w4a16().quantize_acts());
        assert!(Precision::w4a6().quantize_acts());
    }

    #[test]
    #[should_panic]
    fn rejects_silly_bits() {
        Precision::new(1, 8);
    }
}
