//! Evaluation: causal-LM perplexity and log-likelihood scoring.

pub mod tasks;

use crate::model::{Gpt, KvDtype, NullSink, PREFILL_CHUNK};
use crate::tensor::{Matrix, QGemmArena};

/// Numerically stable log-softmax of one logit row, returning only the value
/// at `target`.
pub fn log_prob(logits: &[f32], target: usize) -> f64 {
    let max = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)) as f64;
    let mut lse = 0f64;
    for &v in logits {
        lse += ((v as f64) - max).exp();
    }
    (logits[target] as f64 - max) - lse.ln()
}

/// Perplexity of a token stream, evaluated in non-overlapping windows of
/// `seq_len` (every position except the first of each window is scored —
/// the standard strided PPL protocol).
///
/// Windows run through [`Gpt::forward_logits_chunked`] — the same ragged
/// chunk-batch engine the serving path uses (packed quantized GEMMs over
/// [`PREFILL_CHUNK`]-token tiles, one shared scratch arena across windows)
/// — rather than a second teacher-forced implementation.
pub fn perplexity(model: &Gpt, stream: &[u32], seq_len: usize) -> f64 {
    perplexity_kv_dtype(model, stream, seq_len, KvDtype::F32)
}

/// [`perplexity`] with an explicit KV-cache dtype. `KvDtype::Int8` scores
/// the stream through the int8-quantized cache and fused-dequant attention
/// path, so the drift it reports is exactly the serving-time drift.
pub fn perplexity_kv_dtype(model: &Gpt, stream: &[u32], seq_len: usize, dtype: KvDtype) -> f64 {
    let seq_len = seq_len.min(model.cfg.max_seq);
    let mut arena = QGemmArena::new();
    let mut nll = 0f64;
    let mut count = 0usize;
    let mut start = 0;
    while start + 2 <= stream.len() {
        let end = (start + seq_len).min(stream.len());
        let window = &stream[start..end];
        if window.len() < 2 {
            break;
        }
        let logits =
            model.forward_logits_chunked_dtype(window, PREFILL_CHUNK, dtype, &mut arena);
        for t in 0..window.len() - 1 {
            nll -= log_prob(logits.row(t), window[t + 1] as usize);
            count += 1;
        }
        start = end;
    }
    (nll / count.max(1) as f64).exp()
}

/// Sum log-likelihood of `continuation` given `prompt` (teacher-forced).
pub fn continuation_ll(model: &Gpt, prompt: &[u32], continuation: &[u32]) -> f64 {
    assert!(!continuation.is_empty());
    let mut full = prompt.to_vec();
    full.extend_from_slice(continuation);
    let take = full.len().min(model.cfg.max_seq);
    let full = &full[full.len() - take..];
    let p_len = full.len() - continuation.len();
    let logits = model.forward_logits(full, &mut NullSink);
    let mut ll = 0f64;
    for (k, &tok) in continuation.iter().enumerate() {
        let pos = p_len + k;
        // logits at pos-1 predict token at pos.
        ll += log_prob(logits.row(pos - 1), tok as usize);
    }
    ll
}

/// Length-normalized continuation LL (HellaSwag-style scoring).
pub fn continuation_ll_norm(model: &Gpt, prompt: &[u32], continuation: &[u32]) -> f64 {
    continuation_ll(model, prompt, continuation) / continuation.len() as f64
}

/// Mean NLL difference helper used in reports: ppl_delta = ppl_q − ppl_ref.
pub fn ppl_delta(ppl_q: f64, ppl_ref: f64) -> f64 {
    ppl_q - ppl_ref
}

/// Batched greedy-match accuracy of next-token prediction over a stream —
/// a cheap sanity metric for pretraining quality.
pub fn next_token_accuracy(model: &Gpt, stream: &[u32], seq_len: usize) -> f64 {
    let seq_len = seq_len.min(model.cfg.max_seq);
    let mut hits = 0usize;
    let mut count = 0usize;
    let mut start = 0;
    while start + 2 <= stream.len() && count < 4096 {
        let end = (start + seq_len).min(stream.len());
        let window = &stream[start..end];
        if window.len() < 2 {
            break;
        }
        let logits = model.forward_logits(window, &mut NullSink);
        for t in 0..window.len() - 1 {
            if crate::model::argmax(logits.row(t)) == window[t + 1] as usize {
                hits += 1;
            }
            count += 1;
        }
        start = end;
    }
    hits as f64 / count.max(1) as f64
}

/// Softmax over a full logit row (used by sampling in serving).
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut out: Vec<f32> = logits.iter().map(|&v| (v - max).exp()).collect();
    let sum: f32 = out.iter().sum();
    for v in &mut out {
        *v /= sum;
    }
    out
}

/// The reference logits distance used in integration tests: max |Δ| over
/// the final position.
pub fn logits_max_diff(a: &Matrix, b: &Matrix) -> f32 {
    a.max_diff(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_model;
    use crate::util::rng::Pcg64;

    #[test]
    fn log_prob_matches_manual() {
        let logits = vec![1.0f32, 2.0, 3.0];
        let lp = log_prob(&logits, 2);
        let z: f64 = logits.iter().map(|&x| (x as f64).exp()).sum();
        let want = (3f64.exp() / z).ln();
        assert!((lp - want).abs() < 1e-9);
    }

    #[test]
    fn perplexity_uniform_model_close_to_vocab() {
        // An untrained synthetic model is near-uniform ⇒ PPL ≈ vocab size.
        let model = synthetic_model("micro", 15).unwrap();
        let corpus = crate::data::corpus(model.cfg.vocab_size, "wiki").unwrap();
        let stream = corpus.stream(&mut Pcg64::seed(3), 256);
        let ppl = perplexity(&model, &stream, 32);
        let v = model.cfg.vocab_size as f64;
        assert!(ppl > v * 0.3 && ppl < v * 3.0, "ppl={ppl} vocab={v}");
    }

    #[test]
    fn perplexity_chunked_matches_teacher_forced_reference() {
        // The chunked serving-path PPL must agree with the same windowed
        // protocol evaluated over the teacher-forced forward.
        let model = synthetic_model("micro", 18).unwrap();
        let corpus = crate::data::corpus(model.cfg.vocab_size, "wiki").unwrap();
        let stream = corpus.stream(&mut Pcg64::seed(4), 160);
        let seq_len = 32usize;
        let got = perplexity(&model, &stream, seq_len);
        let mut nll = 0f64;
        let mut count = 0usize;
        let mut start = 0;
        while start + 2 <= stream.len() {
            let end = (start + seq_len).min(stream.len());
            let window = &stream[start..end];
            if window.len() < 2 {
                break;
            }
            let logits = model.forward_logits(window, &mut NullSink);
            for t in 0..window.len() - 1 {
                nll -= log_prob(logits.row(t), window[t + 1] as usize);
                count += 1;
            }
            start = end;
        }
        let want = (nll / count.max(1) as f64).exp();
        assert!(
            (got - want).abs() / want < 1e-3,
            "chunked ppl {got} vs teacher-forced {want}"
        );
    }

    #[test]
    fn int8_kv_perplexity_drift_bounded() {
        // The int8 KV cache must not move perplexity by more than 10%
        // relative to the f32 cache on the same stream — the serving-time
        // quality gate for --kv-bits 8.
        let model = synthetic_model("micro", 15).unwrap();
        let corpus = crate::data::corpus(model.cfg.vocab_size, "wiki").unwrap();
        let stream = corpus.stream(&mut Pcg64::seed(9), 256);
        let ppl_f32 = perplexity_kv_dtype(&model, &stream, 32, KvDtype::F32);
        let ppl_i8 = perplexity_kv_dtype(&model, &stream, 32, KvDtype::Int8);
        let drift = (ppl_i8 / ppl_f32 - 1.0).abs();
        assert!(
            drift <= 0.1,
            "int8 KV ppl drift {drift:.4} (f32 {ppl_f32:.3} vs int8 {ppl_i8:.3})"
        );
    }

    #[test]
    fn continuation_ll_additivity() {
        let model = synthetic_model("micro", 16).unwrap();
        let prompt = vec![3u32, 5, 7];
        let cont = vec![11u32, 13];
        let ll_joint = continuation_ll(&model, &prompt, &cont);
        let ll_a = continuation_ll(&model, &prompt, &cont[..1].to_vec());
        let mut p2 = prompt.clone();
        p2.push(cont[0]);
        let ll_b = continuation_ll(&model, &p2, &cont[1..].to_vec());
        assert!((ll_joint - (ll_a + ll_b)).abs() < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one() {
        let s = softmax(&[0.0, 1.0, -2.0, 5.0]);
        assert!((s.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(s[3] > s[1]);
    }

    #[test]
    fn ll_norm_divides_by_len() {
        let model = synthetic_model("micro", 17).unwrap();
        let ll = continuation_ll(&model, &[1, 2], &[3, 4]);
        let lln = continuation_ll_norm(&model, &[1, 2], &[3, 4]);
        assert!((lln - ll / 2.0).abs() < 1e-12);
    }
}
