//! Synthetic zero-shot evaluation tasks.
//!
//! Stand-ins for the paper's benchmark suites (DESIGN.md §3). Each task is
//! multiple-choice over token continuations scored by (length-normalized)
//! log-likelihood — the same protocol OpenCompass uses for ARC/HellaSwag &
//! co. The tasks probe the grammar rules the models were pretrained on, at
//! increasing difficulty:
//!
//! - `arc_e`  — 2-way verb agreement (easy).
//! - `arc_c`  — 4-way verb agreement with near-class distractors (hard).
//! - `mmlu`   — 4-way mixed rule probing with longer, distracting context.
//! - `hella`  — 4-way multi-token sentence completion, length-normalized.
//! - `piqa`   — 2-way determiner-number agreement.
//! - `gsm`    — long-horizon consistency: subject introduced sentences ago
//!              must still govern the verb (chain "reasoning" stand-in).
//! - `heval`  — structural validity: choose the continuation that keeps the
//!              template well-formed (code-structure stand-in).

use super::{continuation_ll, continuation_ll_norm};
use crate::data::corpus::Corpus;
use crate::data::vocab::{Cat, N_CLASSES};
use crate::model::Gpt;
use crate::util::rng::Pcg64;

/// One multiple-choice instance.
#[derive(Clone, Debug)]
pub struct Task {
    pub prompt: Vec<u32>,
    pub options: Vec<Vec<u32>>,
    pub correct: usize,
    /// length-normalize the option scores (multi-token options).
    pub norm: bool,
}

/// A named task set.
pub struct TaskSet {
    pub name: String,
    pub tasks: Vec<Task>,
}

pub fn task_names() -> Vec<&'static str> {
    vec!["arc_e", "arc_c", "mmlu", "hella", "piqa", "gsm", "heval"]
}

/// Generate `n` instances of the named task.
pub fn generate(corpus: &Corpus, name: &str, n: usize, seed: u64) -> anyhow::Result<TaskSet> {
    let mut rng = Pcg64::new(seed, crate::util::rng::hash_label(name));
    let mut tasks = Vec::with_capacity(n);
    for _ in 0..n {
        let t = match name {
            "arc_e" => agreement_task(corpus, &mut rng, 2, false),
            "arc_c" => agreement_task(corpus, &mut rng, 4, true),
            "mmlu" => mixed_rule_task(corpus, &mut rng),
            "hella" => completion_task(corpus, &mut rng),
            "piqa" => number_task(corpus, &mut rng),
            "gsm" => chain_task(corpus, &mut rng),
            "heval" => structure_task(corpus, &mut rng),
            other => anyhow::bail!("unknown task '{other}'"),
        };
        tasks.push(t);
    }
    Ok(TaskSet { name: name.to_string(), tasks })
}

/// Score a task set: fraction of instances where the correct option has the
/// highest (normalized) LL. Returns accuracy in percent.
///
/// Uses KV-prefix reuse: the prompt is forwarded once per task, every option
/// is scored from a clone of the prompt cache — the same prefix-sharing
/// trick the serving stack uses, cutting cost by ~n_options×.
pub fn evaluate(model: &Gpt, set: &TaskSet) -> f64 {
    let mut hits = 0usize;
    for t in &set.tasks {
        let mut best = (f64::NEG_INFINITY, 0usize);
        // Prefill the prompt once.
        let mut cache = crate::model::KvCache::new(&model.cfg);
        let mut logits = Vec::new();
        for &tok in &t.prompt {
            logits = model.forward_step(tok, &mut cache);
        }
        for (i, opt) in t.options.iter().enumerate() {
            let mut ll = super::log_prob(&logits, opt[0] as usize);
            if opt.len() > 1 {
                let mut c = cache.clone();
                let mut lg = model.forward_step(opt[0], &mut c);
                for &tok in &opt[1..] {
                    ll += super::log_prob(&lg, tok as usize);
                    lg = model.forward_step(tok, &mut c);
                }
            }
            let score = if t.norm { ll / opt.len() as f64 } else { ll };
            if score > best.0 {
                best = (score, i);
            }
        }
        if best.1 == t.correct {
            hits += 1;
        }
    }
    100.0 * hits as f64 / set.tasks.len().max(1) as f64
}

/// Reference (non-cached) scorer kept for the equivalence test.
pub fn evaluate_reference(model: &Gpt, set: &TaskSet) -> f64 {
    let mut hits = 0usize;
    for t in &set.tasks {
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (i, opt) in t.options.iter().enumerate() {
            let ll = if t.norm {
                continuation_ll_norm(model, &t.prompt, opt)
            } else {
                continuation_ll(model, &t.prompt, opt)
            };
            if ll > best.0 {
                best = (ll, i);
            }
        }
        if best.1 == t.correct {
            hits += 1;
        }
    }
    100.0 * hits as f64 / set.tasks.len().max(1) as f64
}

// -- generators -------------------------------------------------------------

fn noun_of_class(c: &Corpus, rng: &mut Pcg64, class: usize) -> u32 {
    // Noun layout: index = block·(2·N_CLASSES) + class·2 + parity.
    let n = c.vocab.count(Cat::Noun);
    let stride = 2 * N_CLASSES;
    let parity = rng.below(2);
    let offset = class * 2 + parity;
    let blocks = (n - offset + stride - 1) / stride;
    // Favor frequent (low-index) nouns the pretrained model has seen a lot.
    let block = rng.below(blocks.min(8).max(1));
    c.vocab.nth(Cat::Noun, block * stride + offset)
}

fn verb_of_class(c: &Corpus, rng: &mut Pcg64, class: usize) -> u32 {
    let n = c.vocab.count(Cat::Verb);
    let blocks = (n - class + N_CLASSES - 1) / N_CLASSES;
    let block = rng.below(blocks.min(8).max(1));
    c.vocab.nth(Cat::Verb, block * N_CLASSES + class)
}

/// DET NOUN_c → pick VERB_c among distractor verbs of other classes.
fn agreement_task(c: &Corpus, rng: &mut Pcg64, n_opts: usize, near: bool) -> Task {
    let class = rng.below(N_CLASSES);
    let noun = noun_of_class(c, rng, class);
    let det = c.vocab.det_for(c.vocab.is_plural_noun(noun), rng.below(4));
    let prompt = vec![det, noun];
    let mut options = vec![vec![verb_of_class(c, rng, class)]];
    for k in 1..n_opts {
        // near-class distractors differ by 1..3; far by anything ≠ class.
        let wrong = if near {
            (class + k) % N_CLASSES
        } else {
            (class + N_CLASSES / 2) % N_CLASSES
        };
        options.push(vec![verb_of_class(c, rng, wrong)]);
    }
    shuffle_to_task(rng, prompt, options, false)
}

/// Longer context with an interleaved distractor clause, 4-way verb choice.
fn mixed_rule_task(c: &Corpus, rng: &mut Pcg64) -> Task {
    let mut prompt = c.sentence(rng); // distractor sentence
    let class = rng.below(N_CLASSES);
    let noun = noun_of_class(c, rng, class);
    prompt.push(c.vocab.det_for(c.vocab.is_plural_noun(noun), rng.below(4)));
    prompt.push(noun);
    let mut options = vec![vec![verb_of_class(c, rng, class)]];
    for k in 1..4 {
        options.push(vec![verb_of_class(c, rng, (class + k) % N_CLASSES)]);
    }
    shuffle_to_task(rng, prompt, options, false)
}

/// Multi-token completion: correct = [VERB_c, DET, NOUN]; distractors break
/// agreement or structure. Length-normalized.
fn completion_task(c: &Corpus, rng: &mut Pcg64) -> Task {
    let class = rng.below(N_CLASSES);
    let noun = noun_of_class(c, rng, class);
    let det = c.vocab.det_for(c.vocab.is_plural_noun(noun), rng.below(4));
    let prompt = vec![det, noun];
    let obj_class = rng.below(N_CLASSES);
    let obj = noun_of_class(c, rng, obj_class);
    let obj_det = c.vocab.det_for(c.vocab.is_plural_noun(obj), rng.below(4));
    let good = vec![verb_of_class(c, rng, class), obj_det, obj];
    let bad1 = vec![verb_of_class(c, rng, (class + 3) % N_CLASSES), obj_det, obj];
    // structure-breaking: verb verb noun
    let rand_class = rng.below(N_CLASSES);
    let bad2 = vec![
        verb_of_class(c, rng, class),
        verb_of_class(c, rng, rand_class),
        obj,
    ];
    // number-breaking object determiner
    let wrong_det = c.vocab.det_for(!c.vocab.is_plural_noun(obj), rng.below(4));
    let bad3 = vec![verb_of_class(c, rng, class), wrong_det, obj];
    shuffle_to_task(rng, prompt, vec![good, bad1, bad2, bad3], true)
}

/// Determiner-number agreement, 2-way.
fn number_task(c: &Corpus, rng: &mut Pcg64) -> Task {
    let class = rng.below(N_CLASSES);
    let noun = noun_of_class(c, rng, class);
    let plural = c.vocab.is_plural_noun(noun);
    let prompt = vec![c.vocab.det_for(plural, rng.below(4))];
    let good = vec![noun];
    // distractor: same class, opposite number
    let mut other = noun;
    for k in 0..c.vocab.count(Cat::Noun) {
        let cand = c.vocab.nth(Cat::Noun, k);
        if c.vocab.class_of(cand) == class && c.vocab.is_plural_noun(cand) != plural {
            other = cand;
            break;
        }
    }
    shuffle_to_task(rng, prompt, vec![good, vec![other]], false)
}

/// Long-horizon: subject sentence, then 1-2 distractor sentences, then the
/// subject's determiner repeats and the verb must agree with the *original*
/// class.
fn chain_task(c: &Corpus, rng: &mut Pcg64) -> Task {
    let class = rng.below(N_CLASSES);
    let noun = noun_of_class(c, rng, class);
    let det = c.vocab.det_for(c.vocab.is_plural_noun(noun), rng.below(4));
    let mut prompt = vec![det, noun, verb_of_class(c, rng, class), c.vocab.nth(Cat::Punct, 0)];
    for _ in 0..1 + rng.below(2) {
        prompt.extend(c.sentence(rng));
    }
    prompt.push(det);
    prompt.push(noun);
    let mut options = vec![vec![verb_of_class(c, rng, class)]];
    for k in 1..4 {
        options.push(vec![verb_of_class(c, rng, (class + k) % N_CLASSES)]);
    }
    shuffle_to_task(rng, prompt, options, false)
}

/// Structural validity: after "DET ADJ? NOUN VERB DET", the continuation
/// must be a NOUN (valid) vs VERB/DET/PUNCT (invalid).
fn structure_task(c: &Corpus, rng: &mut Pcg64) -> Task {
    let class = rng.below(N_CLASSES);
    let noun = noun_of_class(c, rng, class);
    let obj_class = rng.below(N_CLASSES);
    let obj = noun_of_class(c, rng, obj_class);
    let prompt = vec![
        c.vocab.det_for(c.vocab.is_plural_noun(noun), rng.below(4)),
        noun,
        verb_of_class(c, rng, class),
        c.vocab.det_for(c.vocab.is_plural_noun(obj), rng.below(4)),
    ];
    let good = vec![obj];
    let bad1_class = rng.below(N_CLASSES);
    let bad1 = vec![verb_of_class(c, rng, bad1_class)];
    let bad2 = vec![c.vocab.det_for(rng.f64() < 0.5, rng.below(4))];
    let bad3 = vec![c.vocab.nth(Cat::Punct, rng.below(5))];
    shuffle_to_task(rng, prompt, vec![good, bad1, bad2, bad3], false)
}

fn shuffle_to_task(rng: &mut Pcg64, prompt: Vec<u32>, options: Vec<Vec<u32>>, norm: bool) -> Task {
    // options[0] is correct; shuffle positions.
    let n = options.len();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let correct = order.iter().position(|&i| i == 0).unwrap();
    let options = order.into_iter().map(|i| options[i].clone()).collect();
    Task { prompt, options, correct, norm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus;
    use crate::model::synthetic_model;

    fn test_corpus() -> Corpus {
        corpus(512, "wiki").unwrap()
    }

    #[test]
    fn generators_produce_valid_tasks() {
        let c = test_corpus();
        for name in task_names() {
            let set = generate(&c, name, 20, 3).unwrap();
            assert_eq!(set.tasks.len(), 20, "{name}");
            for t in &set.tasks {
                assert!(!t.prompt.is_empty());
                assert!(t.options.len() >= 2);
                assert!(t.correct < t.options.len());
                assert!(t.options.iter().all(|o| !o.is_empty()));
                // options must be distinct
                for i in 0..t.options.len() {
                    for j in i + 1..t.options.len() {
                        assert_ne!(t.options[i], t.options[j], "{name}: dup options");
                    }
                }
            }
        }
        assert!(generate(&c, "nope", 1, 0).is_err());
    }

    #[test]
    fn correct_option_respects_agreement() {
        let c = test_corpus();
        let set = generate(&c, "arc_e", 50, 7).unwrap();
        for t in &set.tasks {
            let noun = t.prompt[1];
            let correct_verb = t.options[t.correct][0];
            assert_eq!(c.vocab.class_of(noun), c.vocab.class_of(correct_verb));
            for (i, opt) in t.options.iter().enumerate() {
                if i != t.correct {
                    assert_ne!(c.vocab.class_of(noun), c.vocab.class_of(opt[0]));
                }
            }
        }
    }

    #[test]
    fn untrained_model_near_chance() {
        let c = test_corpus();
        let model = synthetic_model("micro", 21).unwrap();
        // micro model has vocab 128 but corpus vocab is 512 — build matching corpus
        let c128 = corpus(128, "wiki").unwrap();
        let _ = c;
        let set = generate(&c128, "arc_e", 40, 9).unwrap();
        let acc = evaluate(&model, &set);
        // 2-way chance = 50%; untrained model should be within a wide band.
        assert!((20.0..80.0).contains(&acc), "acc={acc}");
    }

    #[test]
    fn cached_and_reference_scorers_agree() {
        let c128 = corpus(128, "wiki").unwrap();
        let model = synthetic_model("micro", 22).unwrap();
        for name in ["arc_e", "hella", "gsm"] {
            let set = generate(&c128, name, 15, 4).unwrap();
            let a = evaluate(&model, &set);
            let b = evaluate_reference(&model, &set);
            assert!((a - b).abs() < 1e-9, "{name}: {a} vs {b}");
        }
    }

    #[test]
    fn deterministic_generation() {
        let c = test_corpus();
        let a = generate(&c, "hella", 10, 42).unwrap();
        let b = generate(&c, "hella", 10, 42).unwrap();
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.correct, y.correct);
        }
    }
}
