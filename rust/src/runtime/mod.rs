//! PJRT runtime — loads AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the rust request path.
//!
//! Python never runs at serve time: `make artifacts` lowers the Pallas
//! kernel + block forward to HLO text once; this module compiles them with
//! the PJRT CPU client (the `xla` crate wraps xla_extension 0.5.1) and
//! caches the loaded executables keyed by artifact file.
//!
//! Interchange is HLO *text*, not serialized protos — see
//! /opt/xla-example/README.md for the 64-bit-instruction-id gotcha.

use crate::tensor::Matrix;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Manifest entry for one compiled quantized-linear kernel.
#[derive(Clone, Debug)]
pub struct QlinearArtifact {
    pub file: String,
    pub config: String,
    pub layer: String,
    pub t: usize,
    pub d_in: usize,
    pub d_out: usize,
    pub rank: usize,
    pub abits: usize,
}

/// Parsed artifacts manifest.
#[derive(Debug, Default)]
pub struct Manifest {
    pub qlinear: Vec<QlinearArtifact>,
    pub block_fwd: Vec<(String, String)>, // (file, config)
}

impl Manifest {
    pub fn load(hlo_dir: &Path) -> Result<Manifest> {
        let path = hlo_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let mut m = Manifest::default();
        if let Some(arr) = j.get("qlinear").and_then(Json::as_arr) {
            for e in arr {
                m.qlinear.push(QlinearArtifact {
                    file: e.str_field("file")?.to_string(),
                    config: e.str_field("config")?.to_string(),
                    layer: e.str_field("layer")?.to_string(),
                    t: e.int("t")?,
                    d_in: e.int("d_in")?,
                    d_out: e.int("d_out")?,
                    rank: e.int("rank")?,
                    abits: e.int("abits")?,
                });
            }
        }
        if let Some(arr) = j.get("block_fwd").and_then(Json::as_arr) {
            for e in arr {
                m.block_fwd
                    .push((e.str_field("file")?.to_string(), e.str_field("config")?.to_string()));
            }
        }
        Ok(m)
    }
}

/// PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    hlo_dir: PathBuf,
    cache: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    pub fn new(hlo_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(anyhow_xla)?;
        Ok(Runtime { client, hlo_dir: hlo_dir.to_path_buf(), cache: BTreeMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an HLO-text artifact.
    pub fn load(&mut self, file: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(file) {
            let path = self.hlo_dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .map_err(anyhow_xla)
            .with_context(|| format!("parse {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(anyhow_xla)?;
            self.cache.insert(file.to_string(), exe);
        }
        Ok(self.cache.get(file).unwrap())
    }

    pub fn loaded(&self) -> usize {
        self.cache.len()
    }

    /// Execute a compiled qlinear artifact:
    /// inputs (x, m, w_packed, w_scales, la, lb) → y (t × d_out).
    pub fn run_qlinear(
        &mut self,
        art: &QlinearArtifact,
        x: &Matrix,
        m: &[f32],
        w_packed: &[u8],
        w_scales: &[f32],
        la: &Matrix,
        lb: &Matrix,
    ) -> Result<Matrix> {
        anyhow::ensure!(x.rows == art.t && x.cols == art.d_in, "x shape mismatch");
        anyhow::ensure!(la.rows == art.d_out && la.cols == art.rank, "la shape mismatch");
        anyhow::ensure!(lb.rows == art.rank && lb.cols == art.d_in, "lb shape mismatch");
        let lit = |data: &[f32], dims: &[i64]| -> Result<xla::Literal> {
            xla::Literal::vec1(data).reshape(dims).map_err(anyhow_xla)
        };
        let x_l = lit(&x.data, &[art.t as i64, art.d_in as i64])?;
        let m_l = xla::Literal::vec1(m);
        // u8 has no NativeType impl in the crate; build the literal from
        // untyped bytes instead.
        let wp_l = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8,
            &[art.d_out, art.d_in / 2],
            w_packed,
        )
        .map_err(anyhow_xla)?;
        let ws_l = xla::Literal::vec1(w_scales);
        let la_l = lit(&la.data, &[art.d_out as i64, art.rank as i64])?;
        let lb_l = lit(&lb.data, &[art.rank as i64, art.d_in as i64])?;
        let exe = self.load(&art.file)?;
        let result = exe
            .execute::<xla::Literal>(&[x_l, m_l, wp_l, ws_l, la_l, lb_l])
            .map_err(anyhow_xla)?[0][0]
            .to_literal_sync()
            .map_err(anyhow_xla)?;
        let out = result.to_tuple1().map_err(anyhow_xla)?;
        let values = out.to_vec::<f32>().map_err(anyhow_xla)?;
        anyhow::ensure!(values.len() == art.t * art.d_out, "output size mismatch");
        Ok(Matrix::from_vec(art.t, art.d_out, values))
    }
}

fn anyhow_xla(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

/// Reference semantics the compiled kernel must match (mirrors
/// `QuantizedLinear::forward_matrix` for the smooth+quant+lowrank case) —
/// used by `runtime-check` and the integration tests.
pub fn qlinear_reference(
    x: &Matrix,
    m: &[f32],
    w_codes: &[i8],
    d_out: usize,
    w_scales: &[f32],
    la: &Matrix,
    lb: &Matrix,
    abits: u8,
) -> Matrix {
    let d_in = x.cols;
    let inv: Vec<f32> = m.iter().map(|&v| 1.0 / v).collect();
    let xs = x.scale_cols(&inv);
    let mut y = Matrix::zeros(x.rows, d_out);
    for t in 0..x.rows {
        let q = crate::quant::quantize_token(xs.row(t), abits);
        for o in 0..d_out {
            let codes = &w_codes[o * d_in..(o + 1) * d_in];
            let acc = crate::model::linear::dot_i8(codes, &q.codes);
            y[(t, o)] = acc as f32 * q.scale * w_scales[o];
        }
    }
    let z = crate::tensor::matmul_bt(&xs, lb);
    let corr = crate::tensor::matmul(&z, &la.transpose());
    y.add(&corr)
}
