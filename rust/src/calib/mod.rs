//! Calibration capture.
//!
//! Streams calibration batches through the fp model and accumulates, per
//! quantizable linear layer: the f64 channel Gram `XᵀX/tokens`, per-channel
//! mean |x| (the paper's X̄), and a bounded reservoir subsample of
//! activation rows used for error measurement and grid searches.
//!
//! The paper uses 128 sequences × 2048 tokens; we default to 128 × seq_len
//! of the tiny models.

use crate::methods::LayerCalib;
use crate::model::{ActSink, Gpt};
use crate::tensor::Matrix;
use crate::util::rng::Pcg64;
use std::collections::BTreeMap;

/// Running statistics for one layer.
struct LayerAcc {
    d: usize,
    gram: Vec<f64>,
    abs_sum: Vec<f64>,
    tokens: usize,
    /// Reservoir of activation rows (Algorithm R).
    sample: Vec<Vec<f32>>,
    max_sample: usize,
    rng: Pcg64,
}

impl LayerAcc {
    fn new(d: usize, max_sample: usize, rng: Pcg64) -> LayerAcc {
        LayerAcc {
            d,
            gram: vec![0f64; d * d],
            abs_sum: vec![0f64; d],
            tokens: 0,
            sample: Vec::with_capacity(max_sample),
            max_sample,
            rng,
        }
    }

    fn push(&mut self, x: &Matrix) {
        assert_eq!(x.cols, self.d);
        let d = self.d;
        for r in 0..x.rows {
            let row = x.row(r);
            // Gram upper triangle.
            for i in 0..d {
                let xi = row[i] as f64;
                if xi == 0.0 {
                    continue;
                }
                let g = &mut self.gram[i * d..(i + 1) * d];
                for (j, &xj) in row.iter().enumerate().skip(i) {
                    g[j] += xi * xj as f64;
                }
            }
            for (s, &v) in self.abs_sum.iter_mut().zip(row) {
                *s += v.abs() as f64;
            }
            // Reservoir sampling.
            if self.sample.len() < self.max_sample {
                self.sample.push(row.to_vec());
            } else {
                let j = self.rng.below(self.tokens + 1);
                if j < self.max_sample {
                    self.sample[j] = row.to_vec();
                }
            }
            self.tokens += 1;
        }
    }

    fn finish(mut self) -> LayerCalib {
        let d = self.d;
        let n = self.tokens.max(1) as f64;
        for i in 0..d {
            for j in 0..i {
                self.gram[i * d + j] = self.gram[j * d + i];
            }
        }
        for v in &mut self.gram {
            *v /= n;
        }
        let x_abs_mean: Vec<f32> = self.abs_sum.iter().map(|&s| (s / n) as f32).collect();
        let rows = self.sample.len();
        let mut x = Matrix::zeros(rows.max(1), d);
        for (r, row) in self.sample.iter().enumerate() {
            x.row_mut(r).copy_from_slice(row);
        }
        LayerCalib { x, gram: self.gram, x_abs_mean, tokens: self.tokens }
    }
}

/// ActSink that feeds the accumulators.
struct Recorder {
    accs: BTreeMap<String, LayerAcc>,
    max_sample: usize,
    seed: u64,
}

impl ActSink for Recorder {
    fn record(&mut self, key: &str, x: &Matrix) {
        let acc = self.accs.entry(key.to_string()).or_insert_with(|| {
            LayerAcc::new(
                x.cols,
                self.max_sample,
                Pcg64::new(self.seed, crate::util::rng::hash_label(key)),
            )
        });
        acc.push(x);
    }
}

/// Options for a calibration run.
#[derive(Clone, Debug)]
pub struct CalibConfig {
    /// Number of calibration sequences (paper: 128).
    pub n_seqs: usize,
    /// Tokens per sequence.
    pub seq_len: usize,
    /// Activation rows kept per layer for error measurement.
    pub max_sample: usize,
    pub seed: u64,
}

impl Default for CalibConfig {
    fn default() -> Self {
        CalibConfig { n_seqs: 128, seq_len: 64, max_sample: 512, seed: 0xCA11B }
    }
}

/// Run calibration over token sequences. Returns per-layer statistics keyed
/// by `layer_key(block, linear)`.
pub fn calibrate(
    model: &Gpt,
    seqs: &[Vec<u32>],
    cfg: &CalibConfig,
) -> BTreeMap<String, LayerCalib> {
    let mut rec = Recorder { accs: BTreeMap::new(), max_sample: cfg.max_sample, seed: cfg.seed };
    for seq in seqs.iter().take(cfg.n_seqs) {
        let take = seq.len().min(cfg.seq_len).min(model.cfg.max_seq);
        model.forward_logits(&seq[..take], &mut rec);
    }
    rec.accs.into_iter().map(|(k, acc)| (k, acc.finish())).collect()
}

/// Build calibration sequences from a corpus profile.
pub fn calib_sequences(
    vocab_size: usize,
    profile: &str,
    cfg: &CalibConfig,
) -> anyhow::Result<Vec<Vec<u32>>> {
    let corpus = crate::data::corpus(vocab_size, profile)?;
    let mut rng = Pcg64::new(cfg.seed, 0xC0DE);
    Ok((0..cfg.n_seqs)
        .map(|_| corpus.stream(&mut rng, cfg.seq_len))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_model;

    fn small_cfg() -> CalibConfig {
        CalibConfig { n_seqs: 4, seq_len: 16, max_sample: 32, seed: 1 }
    }

    #[test]
    fn captures_every_linear() {
        let model = synthetic_model("micro", 5).unwrap();
        let seqs = calib_sequences(model.cfg.vocab_size, "wiki", &small_cfg()).unwrap();
        let stats = calibrate(&model, &seqs, &small_cfg());
        assert_eq!(stats.len(), model.cfg.n_layers * 4);
        let qkv = &stats["L0.qkv_proj"];
        assert_eq!(qkv.in_features(), model.cfg.d_model);
        assert_eq!(qkv.tokens, 4 * 16);
        let fc2 = &stats["L1.fc2"];
        assert_eq!(fc2.in_features(), model.cfg.d_ff);
    }

    #[test]
    fn gram_is_psd_diag_nonneg() {
        let model = synthetic_model("micro", 6).unwrap();
        let seqs = calib_sequences(model.cfg.vocab_size, "ptb", &small_cfg()).unwrap();
        let stats = calibrate(&model, &seqs, &small_cfg());
        for (k, c) in &stats {
            let d = c.in_features();
            for i in 0..d {
                assert!(c.gram[i * d + i] >= 0.0, "{k} diag[{i}]");
                for j in 0..d {
                    let diff = (c.gram[i * d + j] - c.gram[j * d + i]).abs();
                    assert!(diff < 1e-9, "{k} asym ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn reservoir_bounded() {
        let mut cfg = small_cfg();
        cfg.max_sample = 10;
        let model = synthetic_model("micro", 7).unwrap();
        let seqs = calib_sequences(model.cfg.vocab_size, "wiki", &cfg).unwrap();
        let stats = calibrate(&model, &seqs, &cfg);
        for c in stats.values() {
            assert!(c.x.rows <= 10);
        }
    }

    #[test]
    fn abs_mean_consistent_with_gram_scale() {
        // X̄_i ≤ sqrt(Gram_ii) (Jensen).
        let model = synthetic_model("micro", 8).unwrap();
        let seqs = calib_sequences(model.cfg.vocab_size, "wiki", &small_cfg()).unwrap();
        let stats = calibrate(&model, &seqs, &small_cfg());
        for (k, c) in &stats {
            let d = c.in_features();
            for i in 0..d {
                let rms = c.gram[i * d + i].sqrt() as f32;
                assert!(c.x_abs_mean[i] <= rms * 1.001, "{k} ch{i}");
            }
        }
    }

    #[test]
    fn outlier_channels_visible_in_calib() {
        // The injected outliers must dominate X̄ at qkv inputs.
        let model = synthetic_model("micro", 9).unwrap();
        let seqs = calib_sequences(model.cfg.vocab_size, "wiki", &small_cfg()).unwrap();
        let stats = calibrate(&model, &seqs, &small_cfg());
        let xm = &stats["L0.qkv_proj"].x_abs_mean;
        let mut sorted = xm.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert!(sorted[0] > 5.0 * sorted[sorted.len() / 2]);
    }
}
