//! `repro` — the L3 coordinator CLI.
//!
//! Subcommands (see `repro help`):
//!   gen-corpus   write training token streams for the python pretrain step
//!   calibrate    capture per-layer calibration statistics
//!   quantize     run a PTQ method over a model, save the quantized model
//!   eval         perplexity + zero-shot accuracy of a (quantized) model
//!   serve        run the batching server demo over a quantized model
//!   bench-table  regenerate a paper table (t1..t8)
//!   figure       regenerate a paper figure (f2..f8)
//!   runtime-check load + execute the AOT HLO artifacts via PJRT

use aser::cli_entry;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = cli_entry::run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
