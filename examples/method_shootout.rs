//! Method shootout on one layer — a microscope on the paper's Fig. 6:
//! quantize a single real linear layer with every method and print the
//! remaining integral error ‖WX − ŷ(X)‖_F, rank, extra params, and time.
//!
//! Run: `cargo run --release --example method_shootout -- [layer-key]`

use aser::calib::CalibConfig;
use aser::coordinator::calibrate_model;
use aser::methods::{layer_error_rel, method_by_name, RankPolicy};
use aser::model::load_or_synthetic;
use aser::quant::Precision;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let key = std::env::args().nth(1).unwrap_or_else(|| "L4.fc1".to_string());
    let (model, _) = load_or_synthetic("A", Path::new("artifacts"), 7)?;
    let ccfg = CalibConfig { n_seqs: 24, seq_len: 48, max_sample: 256, seed: 7 };
    let stats = calibrate_model(&model, "wiki", &ccfg)?;
    let calib = stats
        .get(&key)
        .ok_or_else(|| anyhow::anyhow!("unknown layer '{key}' (try L0.qkv_proj)"))?;
    // Recover block/linear from the key to fetch the weight.
    let block: usize = key[1..key.find('.').unwrap()].parse()?;
    let lname = &key[key.find('.').unwrap() + 1..];
    let w = model.get_linear(block, lname).dense_weight().unwrap();

    println!(
        "layer {key}: {}×{}, {} calib tokens\n",
        w.rows,
        w.cols,
        calib.tokens
    );
    println!(
        "{:<14} {:>9} {:>9} {:>7} {:>10} {:>8}",
        "method", "rel W4A8", "rel W4A6", "rank", "+params", "ms"
    );
    for name in
        ["rtn", "llm_int", "smoothquant", "smoothquant+", "awq", "gptq", "lorc", "l2qer", "aser-er", "aser"]
    {
        let method = method_by_name(name, RankPolicy::Fixed(16), 8)?;
        let t = std::time::Instant::now();
        let q8 = method.quantize_layer(w, calib, Precision::w4a8());
        let ms = t.elapsed().as_secs_f64() * 1e3;
        let q6 = method.quantize_layer(w, calib, Precision::w4a6());
        println!(
            "{:<14} {:>9.5} {:>9.5} {:>7} {:>10} {:>8.0}",
            name,
            layer_error_rel(w, &q8, &calib.x),
            layer_error_rel(w, &q6, &calib.x),
            q8.rank(),
            q8.extra_params(),
            ms
        );
    }
    println!("\nExpected ordering (paper): aser < aser-er < l2qer < lorc < smoothed < rtn");
    Ok(())
}
