//! Rank explorer — the paper's Table 4 / Fig. 8 tradeoff, interactively:
//! sweep the cumulative-singular-value threshold α, print per-layer selected
//! ranks, layer error, and the +FLOPs overhead of the compensation branch.
//!
//! Run: `cargo run --release --example rank_explorer -- [model] [alphas]`
//! e.g. `... -- A 0.015,0.05,0.1`

use aser::analysis::selected_rank;
use aser::calib::CalibConfig;
use aser::coordinator::{calibrate_model, run_ptq};
use aser::methods::{method_by_name, RankPolicy};
use aser::model::{layer_key, load_or_synthetic, LINEAR_NAMES};
use aser::quant::Precision;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let model_name = std::env::args().nth(1).unwrap_or_else(|| "A".to_string());
    let alphas: Vec<f64> = std::env::args()
        .nth(2)
        .unwrap_or_else(|| "0.015,0.03,0.05,0.075,0.1".to_string())
        .split(',')
        .map(|s| s.trim().parse().expect("bad alpha"))
        .collect();

    let (model, _) = load_or_synthetic(&model_name, Path::new("artifacts"), 7)?;
    let ccfg = CalibConfig { n_seqs: 24, seq_len: 48, max_sample: 224, seed: 7 };
    let stats = calibrate_model(&model, "wiki", &ccfg)?;

    // Per-layer selected ranks for each α (Fig. 8 view, first + last block).
    println!("selected rank per linear (whitened spectrum):");
    print!("{:<18}", "layer");
    for a in &alphas {
        print!("{:>9}", format!("α={a}"));
    }
    println!();
    for l in [0, model.cfg.n_layers - 1] {
        for name in LINEAR_NAMES {
            let key = layer_key(l, name);
            let w = model.get_linear(l, name).dense_weight().unwrap();
            print!("{key:<18}");
            for &a in &alphas {
                print!("{:>9}", selected_rank(w, &stats[&key], 4, a));
            }
            println!();
        }
    }

    // Whole-model consequence of each α (Table 4 view).
    println!("\nwhole-model ASER @ W4A8 by α:");
    println!("{:<9} {:>10} {:>12} {:>10} {:>9}", "alpha", "mean rank", "mean rel err", "+FLOPs%", "sec");
    for &a in &alphas {
        let (m, _) = load_or_synthetic(&model_name, Path::new("artifacts"), 7)?;
        let method = method_by_name("aser", RankPolicy::Threshold(a), 8)?;
        let t = std::time::Instant::now();
        let (_, rep) = run_ptq(m, &stats, method.as_ref(), Precision::w4a8(), 0)?;
        println!(
            "{:<9} {:>10.2} {:>12.5} {:>10.2} {:>9.1}",
            a,
            rep.mean_rank(),
            rep.mean_rel_error(),
            rep.flops_overhead_pct(),
            t.elapsed().as_secs_f64()
        );
    }
    println!("\nOverhead should scale ~linearly with mean rank (paper Table 4).");
    Ok(())
}
