//! Quickstart — the end-to-end driver (EXPERIMENTS.md §E2E).
//!
//! Loads the pretrained evaluation model (from `make artifacts`; falls back
//! to a synthetic model), runs the full ASER pipeline — calibrate →
//! quantize to W4A8 per-channel → evaluate — and prints the paper's
//! headline comparison: fp16 vs RTN vs L²QER vs ASER perplexity + accuracy.
//!
//! Run: `cargo run --release --example quickstart`

use aser::calib::CalibConfig;
use aser::coordinator::{calibrate_model, run_ptq};
use aser::data::corpus;
use aser::eval::{perplexity, tasks};
use aser::methods::{method_by_name, RankPolicy};
use aser::model::load_or_synthetic;
use aser::quant::Precision;
use aser::util::rng::Pcg64;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    let (model, pretrained) = load_or_synthetic("A", artifacts, 7)?;
    println!(
        "model A ({}; {} params, {} layers, d={})",
        if pretrained { "pretrained" } else { "synthetic fallback — run `make artifacts`" },
        model.cfg.total_params(),
        model.cfg.n_layers,
        model.cfg.d_model
    );

    // 1. Calibrate once (paper: 128 × 2048 tokens; scaled to the tiny model).
    let ccfg = CalibConfig { n_seqs: 32, seq_len: 64, max_sample: 256, seed: 7 };
    let t = std::time::Instant::now();
    let stats = calibrate_model(&model, "wiki", &ccfg)?;
    println!("calibrated {} linear layers in {:.1}s", stats.len(), t.elapsed().as_secs_f64());

    // 2. Evaluation workload (held-out).
    let c = corpus(model.cfg.vocab_size, "wiki")?;
    let stream = c.stream(&mut Pcg64::seed(0xE0E0), 768);
    let arc = tasks::generate(&c, "arc_c", 40, 99)?;

    let ppl_fp = perplexity(&model, &stream, 64);
    let acc_fp = tasks::evaluate(&model, &arc);
    println!("\n{:<22} {:>9} {:>8}", "", "ppl(wiki)", "arc_c%");
    println!("{:<22} {:>9.3} {:>8.1}", "fp16", ppl_fp, acc_fp);

    // 3. Quantize with RTN (baseline), L²QER and ASER; evaluate each.
    let prec = Precision::w4a8();
    for (name, rank, f) in [("rtn", 16, 8), ("l2qer", 16, 8), ("aser", 16, 8)] {
        let (model2, _) = load_or_synthetic("A", artifacts, 7)?;
        let method = method_by_name(name, RankPolicy::Fixed(rank), f)?;
        let t = std::time::Instant::now();
        let (qm, report) = run_ptq(model2, &stats, method.as_ref(), prec, 0)?;
        let q_secs = t.elapsed().as_secs_f64();
        let ppl = perplexity(&qm, &stream, 64);
        let acc = tasks::evaluate(&qm, &arc);
        println!(
            "{:<22} {:>9.3} {:>8.1}   (quantized in {q_secs:.1}s, +{:.2}% FLOPs)",
            format!("{name} @ {prec}"),
            ppl,
            acc,
            report.flops_overhead_pct()
        );
    }
    println!("\nASER should sit closest to the fp16 row — the paper's headline claim.");
    Ok(())
}
