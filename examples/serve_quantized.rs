//! Serving demo: quantize model A with ASER (W4A8) and serve a bursty
//! request trace through the streaming engine, comparing throughput/latency
//! against the fp16 model — the deployment scenario the paper's overhead
//! analysis targets. The quantized pass also demos the request-granular
//! API: one request is streamed token-by-token and cancelled mid-decode.
//!
//! Run: `cargo run --release --example serve_quantized`

use aser::calib::CalibConfig;
use aser::coordinator::{
    calibrate_model, run_ptq, serve_requests, synthetic_requests, BatchConfig, Engine,
    EngineConfig, GenRequest, ServerConfig, TokenEvent,
};
use aser::methods::{method_by_name, RankPolicy};
use aser::model::load_or_synthetic;
use aser::quant::Precision;
use std::path::Path;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    let n_requests = 24;
    let cfg = ServerConfig {
        workers: 2,
        batch: BatchConfig { max_batch: 6, ..Default::default() },
        kv_tokens: 1 << 14,
        ..Default::default()
    };

    for variant in ["fp16", "aser-w4a8"] {
        let (model, _) = load_or_synthetic("A", artifacts, 7)?;
        let model = if variant == "fp16" {
            model
        } else {
            let ccfg = CalibConfig { n_seqs: 24, seq_len: 48, max_sample: 192, seed: 7 };
            let stats = calibrate_model(&model, "wiki", &ccfg)?;
            let method = method_by_name("aser", RankPolicy::Fixed(16), 8)?;
            let (qm, rep) = run_ptq(model, &stats, method.as_ref(), Precision::w4a8(), 0)?;
            println!(
                "[{variant}] quantized: mean rel err {:.4}, weight storage {:.1}% of fp32",
                rep.mean_rel_error(),
                100.0 * 4.25 / 32.0 // int4 codes + scales vs f32
            );
            qm
        };
        let vocab = model.cfg.vocab_size;
        let model = Arc::new(model);
        if variant == "aser-w4a8" {
            // Request-granular API demo: stream one request live, cancel a
            // second mid-decode (EOS stopping off so the doomed request
            // keeps decoding until the cancel lands).
            let engine = Engine::new(
                Arc::clone(&model),
                EngineConfig {
                    workers: 1,
                    kv_tokens: 1 << 14,
                    batch: BatchConfig { stop_on_eos: false, ..Default::default() },
                    ..Default::default()
                },
            );
            let streamed = engine.submit(GenRequest::new(0, vec![2, 9, 4], 8)).unwrap();
            let doomed = engine.submit(GenRequest::new(1, vec![3, 7], 64)).unwrap();
            // Cancel as soon as the doomed stream produces its first token.
            while let Some(ev) = doomed.recv() {
                if matches!(ev, TokenEvent::Token { .. }) {
                    break;
                }
            }
            doomed.cancel();
            print!("[{variant}] streamed tokens:");
            while let Some(ev) = streamed.recv() {
                match ev {
                    TokenEvent::Token { token, .. } => print!(" {token}"),
                    TokenEvent::Finished { reason, .. } => println!(" ({reason:?})"),
                    TokenEvent::PrefillDone { .. } => {}
                }
            }
            let (reason, n_tokens) = loop {
                match doomed.recv() {
                    Some(TokenEvent::Finished { reason, n_tokens, .. }) => {
                        break (reason, n_tokens)
                    }
                    Some(_) => {}
                    None => break (aser::coordinator::FinishReason::Cancelled, 0),
                }
            };
            println!(
                "[{variant}] cancelled after {n_tokens} tokens ({reason:?}); kv in use: {}",
                engine.kv_used_tokens()
            );
            engine.shutdown();
        }
        let reqs = synthetic_requests(vocab, n_requests, 12, 20, 42)?;
        let run = serve_requests(Arc::clone(&model), &cfg, reqs);
        println!(
            "[{variant}] {} reqs | {:.1} tok/s decode | p50 latency {:.0}ms | p95 {:.0}ms | ttft p50 {:.0}ms",
            run.responses.len(),
            run.throughput_tok_s(),
            run.latency_percentile_ms(50.0),
            run.latency_percentile_ms(95.0),
            run.ttft_percentile_ms(50.0),
        );
        for (i, m) in run.per_worker.iter().enumerate() {
            println!(
                "    worker{i}: {} reqs, {} iters, peak batch {}, kv rejects {}, refused {}",
                m.requests, m.iterations, m.peak_batch, m.rejected_capacity, m.rejected_impossible
            );
        }
    }
    Ok(())
}
