//! Serving demo: quantize model A with ASER (W4A8) and serve a bursty
//! request trace through the router + continuous batcher, comparing
//! throughput/latency against the fp16 model — the deployment scenario the
//! paper's overhead analysis targets.
//!
//! Run: `cargo run --release --example serve_quantized`

use aser::calib::CalibConfig;
use aser::coordinator::{
    calibrate_model, run_ptq, serve_requests, synthetic_requests, BatchConfig, ServerConfig,
};
use aser::methods::{method_by_name, RankPolicy};
use aser::model::load_or_synthetic;
use aser::quant::Precision;
use std::path::Path;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    let n_requests = 24;
    let cfg = ServerConfig {
        workers: 2,
        batch: BatchConfig { max_batch: 6, ..Default::default() },
        kv_tokens: 1 << 14,
    };

    for variant in ["fp16", "aser-w4a8"] {
        let (model, _) = load_or_synthetic("A", artifacts, 7)?;
        let model = if variant == "fp16" {
            model
        } else {
            let ccfg = CalibConfig { n_seqs: 24, seq_len: 48, max_sample: 192, seed: 7 };
            let stats = calibrate_model(&model, "wiki", &ccfg)?;
            let method = method_by_name("aser", RankPolicy::Fixed(16), 8)?;
            let (qm, rep) = run_ptq(model, &stats, method.as_ref(), Precision::w4a8(), 0)?;
            println!(
                "[{variant}] quantized: mean rel err {:.4}, weight storage {:.1}% of fp32",
                rep.mean_rel_error(),
                100.0 * 4.25 / 32.0 // int4 codes + scales vs f32
            );
            qm
        };
        let vocab = model.cfg.vocab_size;
        let reqs = synthetic_requests(vocab, n_requests, 12, 20, 42)?;
        let run = serve_requests(Arc::new(model), &cfg, reqs);
        println!(
            "[{variant}] {} reqs | {:.1} tok/s decode | p50 latency {:.0}ms | p95 {:.0}ms | ttft p50 {:.0}ms",
            run.responses.len(),
            run.throughput_tok_s(),
            run.latency_percentile_ms(50.0),
            run.latency_percentile_ms(95.0),
            run.ttft_percentile_ms(50.0),
        );
        for (i, m) in run.per_worker.iter().enumerate() {
            println!(
                "    worker{i}: {} reqs, {} iters, peak batch {}, kv rejects {}, refused {}",
                m.requests, m.iterations, m.peak_batch, m.rejected_capacity, m.rejected_impossible
            );
        }
    }
    Ok(())
}
