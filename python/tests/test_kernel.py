"""L1 correctness: Pallas kernel vs the pure-jnp oracle.

The CORE correctness signal for the compiled hot path. Hypothesis sweeps
shapes / bit-widths / ranks; fixed cases pin hand-computed numbers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aser_matmul, ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# -- reference self-checks ---------------------------------------------------


class TestReference:
    def test_weight_quant_hand_case(self):
        w = jnp.array([[1.0, -2.0, 7.0], [0.5, 0.25, -0.5]])
        codes, scales = ref.quant_weight_per_channel(w, 4)
        assert scales[0] == pytest.approx(1.0)  # amax 7 / qmax 7
        np.testing.assert_array_equal(np.asarray(codes[0]), [1, -2, 7])
        assert scales[1] == pytest.approx(0.5 / 7)

    def test_act_quant_bound(self):
        x = rand(0, 16, 32, scale=3.0)
        codes, scales = ref.quant_act_per_token(x, 8)
        back = codes.astype(jnp.float32) * scales[:, None]
        assert jnp.max(jnp.abs(back - x)) <= 0.5 * jnp.max(scales) + 1e-6
        assert int(jnp.max(jnp.abs(codes.astype(jnp.int32)))) <= 127

    def test_pack_unpack_roundtrip(self):
        codes = jnp.array([[-8, -1, 0, 7], [3, -5, 2, 1]], dtype=jnp.int8)
        packed = ref.pack_int4(codes)
        assert packed.shape == (2, 2)
        back = ref.unpack_int4(packed, 4)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))

    def test_qlinear_ref_a16_equals_dequant_matmul(self):
        w = rand(1, 8, 16, scale=0.1)
        x = rand(2, 4, 16)
        codes, scales = ref.quant_weight_per_channel(w, 4)
        y = ref.qlinear_ref(x, codes, scales, abits=16)
        wq = codes.astype(jnp.float32) * scales[:, None]
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ wq.T), rtol=1e-5, atol=1e-5)

    def test_smoothing_migrates(self):
        # (W·diag(m)) with x/m reproduces Wx when no quantization.
        w = rand(3, 8, 16, scale=0.1)
        x = rand(4, 4, 16)
        m = jnp.abs(rand(5, 16)) + 0.5
        ws = w * m[None, :]
        codes, scales = ref.quant_weight_per_channel(ws, 8)
        y = ref.qlinear_ref(x, codes, scales, abits=16, m=m)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w.T), rtol=5e-2, atol=5e-3)


# -- pallas kernel vs reference ----------------------------------------------


def make_inputs(key, t, d_in, d_out, r, w_scale=0.1):
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    x = jax.random.normal(ks[0], (t, d_in))
    w = jax.random.normal(ks[1], (d_out, d_in)) * w_scale
    m = jnp.abs(jax.random.normal(ks[2], (d_in,))) + 0.5
    la = jax.random.normal(ks[3], (d_out, r)) * 0.05
    lb = jax.random.normal(ks[4], (r, d_in)) * 0.05
    packed, scales = aser_matmul.quantize_weights_int4(w)
    codes = ref.unpack_int4(packed, d_in)
    return x, m, packed, codes, scales, la, lb


class TestPallasKernel:
    @pytest.mark.parametrize("abits", [4, 6, 8])
    def test_matches_reference(self, abits):
        x, m, packed, codes, scales, la, lb = make_inputs(10, 64, 128, 128, 16)
        got = aser_matmul.aser_qlinear(x, m, packed, scales, la, lb, abits=abits)
        want = ref.qlinear_ref(x, codes, scales, abits, m=m, la=la, lb=lb)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_grid_tiling_invariance(self):
        # Different block sizes must not change numerics.
        x, m, packed, codes, scales, la, lb = make_inputs(11, 128, 64, 256, 8)
        a = aser_matmul.aser_qlinear(x, m, packed, scales, la, lb, block_t=32, block_o=64)
        b = aser_matmul.aser_qlinear(x, m, packed, scales, la, lb, block_t=128, block_o=256)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)

    def test_zero_lowrank_is_pure_quant(self):
        x, m, packed, codes, scales, la, lb = make_inputs(12, 64, 64, 64, 4)
        la = jnp.zeros_like(la)
        lb = jnp.zeros_like(lb)
        got = aser_matmul.aser_qlinear(x, m, packed, scales, la, lb)
        want = ref.qlinear_ref(x, codes, scales, 8, m=m)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_outlier_token_survives(self):
        # A token with a huge outlier channel must not produce NaN/Inf.
        x, m, packed, codes, scales, la, lb = make_inputs(13, 64, 64, 64, 4)
        x = x.at[3, 7].set(1e4)
        got = aser_matmul.aser_qlinear(x, m, packed, scales, la, lb)
        assert bool(jnp.all(jnp.isfinite(got)))

    @settings(max_examples=20, deadline=None)
    @given(
        t_blocks=st.integers(1, 3),
        d_in_h=st.sampled_from([32, 64, 96]),
        d_out_b=st.integers(1, 3),
        r=st.sampled_from([1, 4, 16]),
        abits=st.sampled_from([4, 6, 8]),
        key=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, t_blocks, d_in_h, d_out_b, r, abits, key):
        t = 16 * t_blocks
        d_out = 32 * d_out_b
        x, m, packed, codes, scales, la, lb = make_inputs(key, t, d_in_h, d_out, r)
        got = aser_matmul.aser_qlinear(
            x, m, packed, scales, la, lb, abits=abits, block_t=16, block_o=32
        )
        want = ref.qlinear_ref(x, codes, scales, abits, m=m, la=la, lb=lb)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


class TestKernelResourceModel:
    def test_vmem_under_budget(self):
        # Default serving blocks must fit TPU VMEM (~16 MiB).
        assert aser_matmul.vmem_bytes(64, 128, 512, 64) < 16 * 2**20
        assert aser_matmul.vmem_bytes(64, 128, 1024, 64) < 16 * 2**20

    def test_mxu_estimate_monotone(self):
        # Bigger aligned blocks → better MXU utilization.
        small = aser_matmul.mxu_utilization_estimate(32, 32, 256, 64)
        big = aser_matmul.mxu_utilization_estimate(128, 128, 256, 64)
        assert big > small
        assert 0.0 < big <= 1.0
