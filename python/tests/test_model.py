"""L2 correctness: the JAX model (shapes, causality, trainability,
quantized-forward plumbing) and the ATNS exporter round-trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import export, model, pretrain
from compile.model import CONFIGS

jax.config.update("jax_platform_name", "cpu")

CFG = CONFIGS["micro"]


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


class TestForward:
    def test_shapes(self, params):
        tokens = jnp.zeros((2, 8), dtype=jnp.int32)
        logits = model.forward(CFG, params, tokens)
        assert logits.shape == (2, 8, CFG.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_causality(self, params):
        t1 = jnp.array([[1, 2, 3, 4, 5]], dtype=jnp.int32)
        t2 = jnp.array([[1, 2, 3, 9, 9]], dtype=jnp.int32)
        l1 = model.forward(CFG, params, t1)
        l2 = model.forward(CFG, params, t2)
        np.testing.assert_allclose(
            np.asarray(l1[0, :3]), np.asarray(l2[0, :3]), rtol=1e-5, atol=1e-5
        )
        assert not np.allclose(np.asarray(l1[0, 4]), np.asarray(l2[0, 4]))

    def test_rope_position_dependence(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 2, 16))
        y = model.rope(x, CFG)
        assert y.shape == x.shape
        # norms preserved per position/head
        nx = jnp.linalg.norm(x, axis=-1)
        ny = jnp.linalg.norm(y, axis=-1)
        np.testing.assert_allclose(np.asarray(nx), np.asarray(ny), rtol=1e-5)
        # position 0 unchanged
        np.testing.assert_allclose(np.asarray(x[:, 0]), np.asarray(y[:, 0]), atol=1e-6)

    def test_loss_decreases_in_tiny_training(self, params):
        # 30 Adam steps on a repetitive stream must reduce loss.
        stream = np.tile(np.arange(20, dtype=np.int32), 200)
        rng = np.random.default_rng(0)
        p = params
        state = pretrain.adam_init(p)
        first = last = None
        for step in range(30):
            b = pretrain.sample_batch(rng, stream, 4, 16)
            loss, grads = model.jit_loss_grad(CFG, p, b)
            p, state = pretrain.adam_step(p, grads, state, 2e-3)
            first = first if first is not None else float(loss)
            last = float(loss)
        assert last < first * 0.9, f"{first} -> {last}"


class TestQuantizedForward:
    def test_fake_quant_close_at_w8a8(self, params):
        tokens = jnp.arange(12, dtype=jnp.int32)[None, :]
        full = model.forward(CFG, params, tokens)
        q = model.fake_quant_forward(CFG, params, tokens, wbits=8, abits=8)
        # int8 fake-quant is a small perturbation on an untrained model
        rel = float(jnp.linalg.norm(q - full) / jnp.linalg.norm(full))
        assert rel < 0.15, rel

    def test_w4_damages_more_than_w8(self, params):
        tokens = jnp.arange(12, dtype=jnp.int32)[None, :]
        full = model.forward(CFG, params, tokens)
        e4 = float(jnp.linalg.norm(model.fake_quant_forward(CFG, params, tokens, 4, 8) - full))
        e8 = float(jnp.linalg.norm(model.fake_quant_forward(CFG, params, tokens, 8, 8) - full))
        assert e4 > e8

    def test_pallas_qlinear_fn_matches_dense_when_lossless(self, params):
        # With rank-0-equivalent factors and int4 this is lossy, so just
        # exercise plumbing: shapes + finite.
        qparams = model.quantize_params_rtn_int4(CFG, params, rank=4)
        lin = model.make_quantized_linear_fn(qparams)
        tokens = jnp.arange(16, dtype=jnp.int32)[None, :]
        logits = model.forward(CFG, params, tokens, lin)
        assert logits.shape == (1, 16, CFG.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))


class TestOutlierInjection:
    def test_function_preserving(self, params):
        tokens = jnp.arange(10, dtype=jnp.int32)[None, :]
        before = model.forward(CFG, params, tokens)
        injected = pretrain.inject_outliers(
            CFG, jax.tree.map(lambda x: x, params), seed=3
        )
        after = model.forward(CFG, injected, tokens)
        rel = float(jnp.linalg.norm(after - before) / jnp.linalg.norm(before))
        assert rel < 1e-4, rel

    def test_creates_norm_gain_outliers(self, params):
        injected = pretrain.inject_outliers(CFG, jax.tree.map(lambda x: x, params), seed=3)
        g = np.asarray(injected["blocks"][0]["attn_norm"])
        assert g.max() > 5.0  # boosted channels
        assert np.median(g) == pytest.approx(1.0)


class TestExport:
    def test_atns_roundtrip(self, tmp_path, params):
        path = tmp_path / "m.atns"
        export.export_model(CFG, params, path)
        back = export.load(path)
        assert back["embed"].shape == (CFG.vocab_size, CFG.d_model)
        np.testing.assert_allclose(
            back["L0.qkv_proj"], np.asarray(params["blocks"][0]["qkv"]), rtol=1e-6
        )
        assert back["L1.fc2"].shape == (CFG.d_model, CFG.d_ff)

    def test_config_json_fields(self):
        import json

        j = json.loads(export.config_json(CFG))
        assert j["d_model"] == CFG.d_model
        assert j["name"] == "micro"

    def test_mixed_dtypes(self, tmp_path):
        path = tmp_path / "t.atns"
        export.save(
            path,
            {
                "f": np.arange(6, dtype=np.float32).reshape(2, 3),
                "u": np.array([1, 255], dtype=np.uint8),
                "i": np.array([-3, 4], dtype=np.int32),
            },
        )
        back = export.load(path)
        assert back["u"].dtype == np.uint8
        np.testing.assert_array_equal(back["i"], [-3, 4])
