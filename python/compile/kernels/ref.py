"""Pure-jnp reference oracles for the Pallas kernels.

These define the semantics contract three implementations must share:
  1. this reference (tested against hand-computed cases),
  2. the Pallas kernel in `aser_matmul.py` (tested against 1 by pytest),
  3. the rust serving hot path `model::linear::forward_quant_token`
     (tested against exported vectors in rust integration tests).

Conventions match the paper + rust side:
  - weights W: (d_out, d_in), per-output-channel symmetric int grid
  - activations X: (T, d_in), per-token symmetric int grid
  - smoothing m: (d_in,) divisor on activations (W was pre-multiplied)
  - low-rank: y += (x_s @ L_Bᵀ) @ L_Aᵀ on the *unquantized* smoothed acts
"""

import jax.numpy as jnp


def qmax_for(bits: int) -> float:
    """Symmetric grid max: int8 -> 127, int4 -> 7."""
    return float(2 ** (bits - 1) - 1)


def quant_weight_per_channel(w, bits: int):
    """RTN per-output-channel symmetric quantization.

    Returns (codes int8 (d_out, d_in), scales f32 (d_out,)).
    """
    qmax = qmax_for(bits)
    amax = jnp.max(jnp.abs(w), axis=1)
    scales = jnp.where(amax > 0, amax / qmax, 1.0)
    codes = jnp.clip(jnp.round(w / scales[:, None]), -qmax, qmax).astype(jnp.int8)
    return codes, scales.astype(jnp.float32)


def quant_act_per_token(x, bits: int):
    """Per-token symmetric quantization.

    Returns (codes int8 (T, d), scales f32 (T,)).
    """
    qmax = qmax_for(bits)
    amax = jnp.max(jnp.abs(x), axis=1)
    scales = jnp.where(amax > 0, amax / qmax, 1.0)
    codes = jnp.clip(jnp.round(x / scales[:, None]), -qmax, qmax).astype(jnp.int8)
    return codes, scales.astype(jnp.float32)


def fake_quant_act(x, bits: int):
    codes, scales = quant_act_per_token(x, bits)
    return codes.astype(jnp.float32) * scales[:, None]


def pack_int4(codes):
    """Pack int8 codes in [-8, 7] two per byte, low nibble first.

    codes: (d_out, d_in) with d_in even -> (d_out, d_in // 2) uint8.
    """
    lo = codes[:, 0::2].astype(jnp.uint8) & 0x0F
    hi = codes[:, 1::2].astype(jnp.uint8) & 0x0F
    return lo | (hi << 4)


def unpack_int4(packed, d_in: int):
    """Inverse of pack_int4, sign-extending 4-bit two's complement."""
    lo = (packed & 0x0F).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    lo = ((lo ^ 8) - 8).astype(jnp.int8)
    hi = ((hi ^ 8) - 8).astype(jnp.int8)
    out = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)
    return out[:, :d_in]


def qlinear_ref(x, w_codes, w_scales, abits: int, m=None, la=None, lb=None):
    """Reference quantized linear forward.

    x: (T, d_in) f32; w_codes: (d_out, d_in) int8; w_scales: (d_out,).
    abits == 16 disables activation quantization.
    Returns (T, d_out) f32.
    """
    xs = x / m[None, :] if m is not None else x
    if abits == 16:
        y = xs @ (w_codes.astype(jnp.float32) * w_scales[:, None]).T
    else:
        xc, xscale = quant_act_per_token(xs, abits)
        acc = xc.astype(jnp.float32) @ w_codes.astype(jnp.float32).T
        y = acc * xscale[:, None] * w_scales[None, :]
    if la is not None and lb is not None:
        y = y + (xs @ lb.T) @ la.T
    return y


def dense_ref(x, w):
    """fp32 reference: y = x Wᵀ."""
    return x @ w.T
