"""Pallas kernel: fused ASER quantized linear (the deployed hot path).

One pallas_call fuses, per (token-block × output-block) grid cell:
  1. activation smoothing              x_s = x / m           (VPU elementwise)
  2. per-token int quantization        amax row-reduce + round (VPU)
  3. int4 weight dequant-in-VMEM       nibble unpack of packed W (VPU)
  4. main GEMM on integer codes        (MXU-shaped (bt, d_in)·(d_in, bo))
  5. low-rank correction               (x_s @ L_Bᵀ) @ L_Aᵀ    (skinny MXU)

HARDWARE ADAPTATION (DESIGN.md §6): the CUDA version of this pipeline keeps
int4 weights in HBM, dequantizes in shared memory per threadblock, and runs
the LoRA-style branch as two skinny GEMMs. On TPU we express the same
schedule with BlockSpecs: packed weights stream HBM→VMEM per output block
(4-bit traffic), the unpack + dequant happens in VMEM registers, the main
contraction targets the MXU, and the r≤64 low-rank factors are small enough
to pin entirely in VMEM across grid steps.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are identical and that is what the tests pin down.

VMEM footprint per grid cell (f32 words unless noted), bt=block_t, bo=block_o:
  x block        bt·d_in
  packed W       bo·d_in/2 bytes (uint8)
  unpacked codes bo·d_in
  L_A block      bo·r
  L_B            r·d_in   (pinned, shared across grid)
  y block        bt·bo
For the default bt=64, bo=128, d_in=512, r=64 that is ≈ 0.62 MiB — far
under the ~16 MiB VMEM budget; see DESIGN.md §Perf for the MXU utilization
estimate.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _kernel(x_ref, m_ref, wp_ref, ws_ref, la_ref, lb_ref, o_ref, *, abits, d_in):
    """One grid cell: (bt, d_in) x-block × (bo, d_in) w-block → (bt, bo)."""
    x = x_ref[...]  # (bt, d_in)
    m = m_ref[...]  # (d_in,)
    xs = x / m[None, :]
    # --- per-token quantization (VPU row reduce) ---
    qmax = ref.qmax_for(abits)
    amax = jnp.max(jnp.abs(xs), axis=1)
    xscale = jnp.where(amax > 0, amax / qmax, 1.0)
    xq = jnp.clip(jnp.round(xs / xscale[:, None]), -qmax, qmax)
    # --- int4 nibble unpack + dequant in VMEM ---
    packed = wp_ref[...]  # (bo, d_in // 2) uint8
    lo = (packed & 0x0F).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    lo = (lo ^ 8) - 8
    hi = (hi ^ 8) - 8
    wq = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)[:, :d_in]
    wq = wq.astype(jnp.float32)  # codes exact in f32
    # --- main contraction (MXU) ---
    acc = jax.lax.dot_general(
        xq, wq, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    y = acc * xscale[:, None] * ws_ref[...][None, :]
    # --- low-rank epilogue (skinny MXU) ---
    z = jax.lax.dot_general(
        xs, lb_ref[...], (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bt, r)
    y = y + jax.lax.dot_general(
        z, la_ref[...], (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] = y


def aser_qlinear(x, m, w_packed, w_scales, la, lb, *, abits=8, block_t=64, block_o=128):
    """Fused W4A{abits} linear with smoothing + low-rank compensation.

    x: (T, d_in) f32
    m: (d_in,) smoothing divisor (ones = no smoothing)
    w_packed: (d_out, d_in//2) uint8 nibble-packed int4 codes
    w_scales: (d_out,) per-channel scales
    la: (d_out, r), lb: (r, d_in)
    Returns (T, d_out) f32.
    """
    t, d_in = x.shape
    d_out = w_packed.shape[0]

    def fit(pref, n):
        """Largest divisor of n that is ≤ pref (block shapes must tile)."""
        b = min(pref, n)
        while n % b != 0:
            b -= 1
        return b

    bt = fit(block_t, t)
    bo = fit(block_o, d_out)
    grid = (t // bt, d_out // bo)
    kernel = functools.partial(_kernel, abits=abits, d_in=d_in)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d_in), lambda i, j: (i, 0)),          # x: stream T
            pl.BlockSpec((d_in,), lambda i, j: (0,)),               # m: pinned
            pl.BlockSpec((bo, d_in // 2), lambda i, j: (j, 0)),     # packed W
            pl.BlockSpec((bo,), lambda i, j: (j,)),                 # w scales
            pl.BlockSpec((bo, la.shape[1]), lambda i, j: (j, 0)),   # L_A block
            pl.BlockSpec((lb.shape[0], d_in), lambda i, j: (0, 0)),  # L_B pinned
        ],
        out_specs=pl.BlockSpec((bt, bo), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, d_out), jnp.float32),
        interpret=True,
    )(x, m, w_packed, w_scales, la, lb)


def quantize_weights_int4(w):
    """Per-channel int4 RTN → (packed uint8, scales). Build-time helper."""
    codes, scales = ref.quant_weight_per_channel(w, 4)
    return ref.pack_int4(codes), scales


def vmem_bytes(block_t, block_o, d_in, r):
    """VMEM footprint estimate (bytes) for one grid cell — used by the
    DESIGN.md §Perf table and asserted < 16 MiB by tests."""
    f32 = 4
    return (
        block_t * d_in * f32          # x block
        + d_in * f32                  # m
        + block_o * d_in // 2         # packed weights (u8)
        + block_o * d_in * f32        # unpacked codes
        + block_o * r * f32           # L_A block
        + r * d_in * f32              # L_B
        + block_t * block_o * f32     # y block
        + block_t * d_in * f32        # xq scratch
    )


def mxu_utilization_estimate(block_t, block_o, d_in, r):
    """Fraction of issued MXU work that is 'useful' vs 128×128-pad waste.

    The MXU processes 128×128×128 tiles; blocks smaller than 128 in any
    contraction dim waste the remainder. This mirrors how the paper reports
    kernel efficiency relative to the A100 tensor-core roofline.
    """
    def eff(dim):
        return dim / (128 * ((dim + 127) // 128))

    main = eff(block_t) * eff(block_o) * eff(d_in)
    lowrank = eff(block_t) * eff(r) * eff(d_in)
    main_flops = 2 * block_t * block_o * d_in
    lr_flops = 2 * block_t * r * (d_in + block_o)
    return (main * main_flops + lowrank * lr_flops) / (main_flops + lr_flops)
