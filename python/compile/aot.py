"""AOT: lower the L2/L1 computations to HLO *text* artifacts for the rust
PJRT runtime.

Interchange is HLO text, NOT serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 (what the `xla`
crate links) rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Artifacts (artifacts/hlo/):
  qlinear_<shape>.hlo.txt   — fused W4A8 ASER linear (pallas, interpret)
                              for the serving shapes of each model config
  block_fwd_<cfg>.hlo.txt   — one fp32 transformer block forward
  manifest.json             — shapes + arg order for the rust loader

Usage: python -m compile.aot --out ../artifacts [--configs A,B]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import aser_matmul
from .model import CONFIGS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_qlinear(t, d_in, d_out, r, abits=8):
    """Fused quantized linear for fixed shapes; returns HLO text."""

    def fn(x, m, wp, ws, la, lb):
        return (aser_matmul.aser_qlinear(x, m, wp, ws, la, lb, abits=abits, block_t=min(64, t)),)

    spec = [
        jax.ShapeDtypeStruct((t, d_in), jnp.float32),
        jax.ShapeDtypeStruct((d_in,), jnp.float32),
        jax.ShapeDtypeStruct((d_out, d_in // 2), jnp.uint8),
        jax.ShapeDtypeStruct((d_out,), jnp.float32),
        jax.ShapeDtypeStruct((d_out, r), jnp.float32),
        jax.ShapeDtypeStruct((r, d_in), jnp.float32),
    ]
    return to_hlo_text(jax.jit(fn).lower(*spec))


def lower_block_fwd(cfg):
    """One fp32 block forward (B=1): h (T, d) + params → h' (T, d)."""
    t = 64

    def fn(h, attn_norm, qkv, out_proj, ffn_norm, fc1, fc2):
        p = {
            "attn_norm": attn_norm,
            "qkv": qkv,
            "out_proj": out_proj,
            "ffn_norm": ffn_norm,
            "fc1": fc1,
            "fc2": fc2,
        }
        return (model.block_forward(cfg, p, h[None], model._dense_linear)[0],)

    d = cfg.d_model
    spec = [
        jax.ShapeDtypeStruct((t, d), jnp.float32),
        jax.ShapeDtypeStruct((d,), jnp.float32),
        jax.ShapeDtypeStruct((3 * d, d), jnp.float32),
        jax.ShapeDtypeStruct((d, d), jnp.float32),
        jax.ShapeDtypeStruct((d,), jnp.float32),
        jax.ShapeDtypeStruct((2 * cfg.d_ff, d), jnp.float32),
        jax.ShapeDtypeStruct((d, cfg.d_ff), jnp.float32),
    ]
    return to_hlo_text(jax.jit(fn).lower(*spec)), t


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="A")
    ap.add_argument("--rank", type=int, default=64)
    args = ap.parse_args()
    hlo_dir = os.path.join(args.out, "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    manifest = {"qlinear": [], "block_fwd": []}

    for name in args.configs.split(","):
        cfg = CONFIGS[name.strip()]
        d = cfg.d_model
        r = min(args.rank, d // 2)
        # Serving shapes: the four block linears at batch-token tile T=64.
        shapes = {
            "qkv_proj": (d, 3 * d),
            "out_proj": (d, d),
            "fc1": (d, 2 * cfg.d_ff),
            "fc2": (cfg.d_ff, d),
        }
        t = 64
        for lname, (d_in, d_out) in shapes.items():
            fname = f"qlinear_{cfg.name}_{lname}_t{t}.hlo.txt"
            text = lower_qlinear(t, d_in, d_out, r)
            with open(os.path.join(hlo_dir, fname), "w") as f:
                f.write(text)
            manifest["qlinear"].append(
                {
                    "file": fname,
                    "config": cfg.name,
                    "layer": lname,
                    "t": t,
                    "d_in": d_in,
                    "d_out": d_out,
                    "rank": r,
                    "abits": 8,
                }
            )
            print(f"wrote {fname} ({len(text)} chars)")
        text, t_blk = lower_block_fwd(cfg)
        fname = f"block_fwd_{cfg.name}.hlo.txt"
        with open(os.path.join(hlo_dir, fname), "w") as f:
            f.write(text)
        manifest["block_fwd"].append(
            {"file": fname, "config": cfg.name, "t": t_blk, "d_model": d, "d_ff": cfg.d_ff}
        )
        print(f"wrote {fname} ({len(text)} chars)")

    with open(os.path.join(hlo_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['qlinear'])} qlinear, {len(manifest['block_fwd'])} block")


if __name__ == "__main__":
    main()
