"""ATNS tensor-file writer/reader — python twin of `rust/src/util/io.rs`.

Format ("ATNS" v1, little-endian): see the rust module docs. Used to hand
pretrained weights (and cross-language reference activations) from the
build path to the rust runtime.
"""

import struct

import numpy as np

MAGIC = b"ATNS"
DTYPES = {np.dtype("float32"): 0, np.dtype("int8"): 1, np.dtype("uint8"): 2, np.dtype("int32"): 3}
DTYPES_INV = {0: np.float32, 1: np.int8, 2: np.uint8, 3: np.int32}


def save(path, tensors):
    """tensors: dict[str, np.ndarray] (f32/i8/u8/i32)."""
    import os

    os.makedirs(os.path.dirname(str(path)) or ".", exist_ok=True)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in DTYPES:
                arr = arr.astype(np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(struct.pack("<B", DTYPES[arr.dtype]))
            f.write(arr.tobytes())


def load(path):
    """Returns dict[str, np.ndarray]."""
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, f"{path}: bad magic"
        version, n = struct.unpack("<II", f.read(8))
        assert version == 1
        for _ in range(n):
            (name_len,) = struct.unpack("<I", f.read(4))
            name = f.read(name_len).decode("utf-8")
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = [struct.unpack("<Q", f.read(8))[0] for _ in range(ndim)]
            (tag,) = struct.unpack("<B", f.read(1))
            dt = np.dtype(DTYPES_INV[tag])
            count = int(np.prod(dims)) if dims else 1
            arr = np.frombuffer(f.read(count * dt.itemsize), dtype=dt).reshape(dims)
            out[name] = arr
    return out


def export_model(cfg, params, path):
    """Write model params using the rust loader's naming scheme."""
    t = {
        "embed": np.asarray(params["embed"]),
        "lm_head": np.asarray(params["lm_head"]),
        "final_norm": np.asarray(params["final_norm"]),
    }
    for l, p in enumerate(params["blocks"]):
        t[f"L{l}.attn_norm"] = np.asarray(p["attn_norm"])
        t[f"L{l}.ffn_norm"] = np.asarray(p["ffn_norm"])
        t[f"L{l}.qkv_proj"] = np.asarray(p["qkv"])
        t[f"L{l}.out_proj"] = np.asarray(p["out_proj"])
        t[f"L{l}.fc1"] = np.asarray(p["fc1"])
        t[f"L{l}.fc2"] = np.asarray(p["fc2"])
    save(path, t)


def config_json(cfg):
    import json

    return json.dumps(
        {
            "name": cfg.name,
            "vocab_size": cfg.vocab_size,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "rope_base": cfg.rope_base,
            "norm_eps": cfg.norm_eps,
            "outlier_frac": cfg.outlier_frac,
            "outlier_gain": cfg.outlier_gain,
        },
        indent=2,
    )
