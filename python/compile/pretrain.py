"""Pretrain the tiny evaluation models on the synthetic corpus.

Build-time only (`make artifacts`). Pipeline per model config:
  1. read the rust-generated training stream
     (artifacts/corpus/train_v{vocab}.bin — raw little-endian u32 tokens;
     `repro gen-corpus` writes it, keeping the grammar single-sourced in
     rust),
  2. train with hand-rolled Adam (optax is not in the offline env),
  3. inject function-preserving outlier channels (the activation-outlier
     phenomenon ASER exploits; exact at fp32, see DESIGN.md §3),
  4. export weights.atns + config.json + ref_logits.atns (cross-language
     check consumed by rust integration tests).

Usage: python -m compile.pretrain --models A,B --steps 300 --out ../artifacts
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import export, model
from .model import CONFIGS


def load_stream(path):
    return np.fromfile(path, dtype=np.uint32).astype(np.int32)


def sample_batch(rng, stream, batch, seq):
    starts = rng.integers(0, len(stream) - seq - 1, size=batch)
    return jnp.asarray(np.stack([stream[s : s + seq + 1] for s in starts]))


def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    params = jax.tree.map(
        lambda p, mi, vi: p - lr * (mi * mhat_scale) / (jnp.sqrt(vi * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return params, {"m": m, "v": v, "t": t}


def lr_schedule(step, total, peak):
    warmup = max(10, total // 20)
    if step < warmup:
        return peak * (step + 1) / warmup
    frac = (step - warmup) / max(1, total - warmup)
    return peak * 0.5 * (1 + np.cos(np.pi * frac))


def inject_outliers(cfg, params, seed):
    """Post-hoc variant: boost RMSNorm gains and divide the consuming
    linear's columns — function-preserving at fp32. NOTE: this leaves
    X̄·W̄ invariant, so ASER's joint outlier criterion cannot see these
    channels; prefer `seed_outliers_at_init` + training (below), which
    grows *bona fide* outliers the way real LLMs do."""
    rng = np.random.default_rng(seed)
    d = cfg.d_model
    n_out = max(1, round(d * cfg.outlier_frac))
    for p in params["blocks"]:
        for norm_key, lin_key in [("attn_norm", "qkv"), ("ffn_norm", "fc1")]:
            chans = rng.choice(d, size=n_out, replace=False)
            gains = cfg.outlier_gain * np.exp(rng.normal(0, 0.4, size=n_out))
            norm = np.asarray(p[norm_key]).copy()
            w = np.asarray(p[lin_key]).copy()
            for c, g in zip(chans, gains):
                norm[c] *= g
                w[:, c] /= g
            p[norm_key] = jnp.asarray(norm)
            p[lin_key] = jnp.asarray(w)
    return params


def seed_outliers_at_init(cfg, params, seed):
    """Boost ~outlier_frac of RMSNorm gains BEFORE training. Training then
    adapts the consuming weights around the hot channels, so the final model
    carries genuine activation outliers whose weight columns are NOT the
    exact inverse of the gain — X̄ and X̄·W̄ both expose them, matching the
    phenomenology the paper exploits (its Fig. 4)."""
    rng = np.random.default_rng(seed)
    d = cfg.d_model
    n_out = max(1, round(d * cfg.outlier_frac))
    for p in params["blocks"]:
        for norm_key in ["attn_norm", "ffn_norm"]:
            chans = rng.choice(d, size=n_out, replace=False)
            gains = cfg.outlier_gain * np.exp(rng.normal(0, 0.4, size=n_out))
            norm = np.asarray(p[norm_key]).copy()
            for c, g in zip(chans, gains):
                norm[c] *= g
            p[norm_key] = jnp.asarray(norm)
    return params


def train_model(name, stream, steps, batch, seq, lr, seed, log_every=50):
    cfg = CONFIGS[name]
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    params = seed_outliers_at_init(cfg, params, seed + 3)
    state = adam_init(params)
    rng = np.random.default_rng(seed + 1)
    seq = min(seq, cfg.max_seq - 1)
    losses = []
    t0 = time.time()
    for step in range(steps):
        b = sample_batch(rng, stream, batch, seq)
        loss, grads = model.jit_loss_grad(cfg, params, b)
        params, state = adam_step(params, grads, state, lr_schedule(step, steps, lr))
        losses.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            print(
                f"[{name}] step {step:4d}  loss {loss:.4f}  "
                f"({time.time() - t0:.0f}s)",
                flush=True,
            )
    return cfg, params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="A,B,C,D,E,F")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--steps-large", type=int, default=0, help="override for C/F (0 = same)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()

    for name in args.models.split(","):
        name = name.strip()
        cfg = CONFIGS[name]
        corpus_path = os.path.join(args.out, "corpus", f"train_v{cfg.vocab_size}.bin")
        stream = load_stream(corpus_path)
        print(f"[{name}] corpus {len(stream)} tokens, model {cfg.d_model}d×{cfg.n_layers}L")
        steps = args.steps
        if args.steps_large and cfg.d_model >= 448:
            steps = args.steps_large
        cfg, params, losses = train_model(
            name, stream, steps, args.batch, args.seq, args.lr, args.seed
        )

        mdir = os.path.join(args.out, "models", name)
        os.makedirs(mdir, exist_ok=True)
        export.export_model(cfg, params, os.path.join(mdir, "weights.atns"))
        with open(os.path.join(mdir, "config.json"), "w") as f:
            f.write(export.config_json(cfg))
        # Cross-language reference: logits for a fixed token sequence.
        ref_tokens = np.arange(1, 17, dtype=np.int32) % cfg.vocab_size
        logits = model.forward(cfg, params, jnp.asarray(ref_tokens)[None, :])[0]
        export.save(
            os.path.join(mdir, "ref_logits.atns"),
            {
                "tokens": ref_tokens.astype(np.int32),
                "logits": np.asarray(logits, dtype=np.float32),
                "final_loss": np.asarray(losses[-10:], dtype=np.float32),
            },
        )
        print(f"[{name}] exported to {mdir} (final loss {np.mean(losses[-10:]):.4f})")


if __name__ == "__main__":
    main()
