"""L2: the JAX transformer — build-time twin of `rust/src/model/gpt.rs`.

Must match the rust forward bit-for-bit up to f32 rounding:
RMSNorm(eps) → fused qkv → rope (half-split) → causal MHSA → out_proj →
residual; RMSNorm → fused fc1 (gate‖up) → SwiGLU → fc2 → residual;
final RMSNorm → lm_head. The cross-language contract is pinned by
`tests/test_model.py` (shapes/causality) and by the rust integration test
over exported reference logits (`artifacts/models/<name>/ref_logits.atns`).
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import aser_matmul, ref


@dataclasses.dataclass(frozen=True)
class Config:
    name: str
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int
    rope_base: float = 10_000.0
    norm_eps: float = 1e-5
    outlier_frac: float = 0.01
    outlier_gain: float = 25.0

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


# Mirror of rust ModelConfig::by_name (keep in sync — checked by the
# config.json the exporter writes).
CONFIGS = {
    "A": Config("A", 512, 256, 8, 8, 512, 256),
    "B": Config("B", 512, 320, 6, 8, 640, 256, outlier_frac=0.015, outlier_gain=45.0),
    "C": Config("C", 512, 512, 8, 8, 1024, 256, outlier_gain=30.0),
    "D": Config("D", 512, 384, 7, 8, 768, 256, outlier_gain=18.0),
    "E": Config("E", 512, 448, 6, 8, 896, 256, outlier_frac=0.012, outlier_gain=35.0),
    "F": Config("F", 512, 512, 7, 16, 1024, 256, outlier_frac=0.012, outlier_gain=40.0),
    "micro": Config("micro", 128, 64, 2, 4, 128, 64),
}


def init_params(cfg: Config, key):
    """GPT-2-style init matching rust `synthetic_model` scale choices."""
    std = 0.02
    resid_std = std / (2.0 * cfg.n_layers) ** 0.5
    keys = jax.random.split(key, 2 + cfg.n_layers)
    params = {
        "embed": jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model)) * std,
        "lm_head": jax.random.normal(keys[1], (cfg.vocab_size, cfg.d_model)) * std,
        "final_norm": jnp.ones(cfg.d_model),
        "blocks": [],
    }
    for l in range(cfg.n_layers):
        ks = jax.random.split(keys[2 + l], 4)
        params["blocks"].append(
            {
                "attn_norm": jnp.ones(cfg.d_model),
                "qkv": jax.random.normal(ks[0], (3 * cfg.d_model, cfg.d_model)) * std,
                "out_proj": jax.random.normal(ks[1], (cfg.d_model, cfg.d_model)) * resid_std,
                "ffn_norm": jnp.ones(cfg.d_model),
                "fc1": jax.random.normal(ks[2], (2 * cfg.d_ff, cfg.d_model)) * std,
                "fc2": jax.random.normal(ks[3], (cfg.d_model, cfg.d_ff)) * resid_std,
            }
        )
    return params


def rmsnorm(x, gain, eps):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def rope(x, cfg: Config):
    """Half-split rotary over (B, T, nh, hd) — matches rust rope_inplace."""
    b, t, nh, hd = x.shape
    half = hd // 2
    pos = jnp.arange(t)[:, None]
    freq = cfg.rope_base ** (-2.0 * jnp.arange(half) / hd)[None, :]
    angle = pos * freq  # (T, half)
    sin, cos = jnp.sin(angle), jnp.cos(angle)
    a, bb = x[..., :half], x[..., half:]
    sin = sin[None, :, None, :]
    cos = cos[None, :, None, :]
    return jnp.concatenate([a * cos - bb * sin, a * sin + bb * cos], axis=-1)


def block_forward(cfg: Config, p, h, linear_fn):
    """One transformer block. `linear_fn(name, params_entry, x2d) -> y2d`
    lets the quantized variant reroute the four linears through kernels."""
    b, t, d = h.shape
    x = rmsnorm(h, p["attn_norm"], cfg.norm_eps)
    qkv = linear_fn("qkv_proj", p["qkv"], x.reshape(b * t, d)).reshape(b, t, 3 * d)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = rope(q.reshape(b, t, cfg.n_heads, cfg.head_dim), cfg)
    k = rope(k.reshape(b, t, cfg.n_heads, cfg.head_dim), cfg)
    v = v.reshape(b, t, cfg.n_heads, cfg.head_dim)
    scale = 1.0 / cfg.head_dim**0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask[None, None, :, :], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", attn, v).reshape(b, t, d)
    h = h + linear_fn("out_proj", p["out_proj"], ctx.reshape(b * t, d)).reshape(b, t, d)

    x2 = rmsnorm(h, p["ffn_norm"], cfg.norm_eps)
    gu = linear_fn("fc1", p["fc1"], x2.reshape(b * t, d)).reshape(b, t, 2 * cfg.d_ff)
    gate, up = gu[..., : cfg.d_ff], gu[..., cfg.d_ff :]
    act = jax.nn.silu(gate) * up
    h = h + linear_fn("fc2", p["fc2"], act.reshape(b * t, cfg.d_ff)).reshape(b, t, d)
    return h


def _dense_linear(name, w, x):
    return x @ w.T


def forward(cfg: Config, params, tokens, linear_fn=_dense_linear):
    """tokens: (B, T) int32 → logits (B, T, vocab)."""
    h = params["embed"][tokens]
    for p in params["blocks"]:
        h = block_forward(cfg, p, h, linear_fn)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return h @ params["lm_head"].T


def loss_fn(cfg: Config, params, batch):
    """Next-token cross-entropy; batch: (B, T+1)."""
    inputs, targets = batch[:, :-1], batch[:, 1:]
    logits = forward(cfg, params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


# -- quantized forward (serving semantics, used by AOT) ---------------------


def make_quantized_linear_fn(qparams, abits=8):
    """qparams: {layer_key: dict(w_packed, w_scales, m, la, lb)} — reroutes
    the four block linears through the fused Pallas kernel."""
    counter = {"layer": 0, "seen": {}}

    def linear_fn(name, w, x):
        # Track which block we're in by counting qkv_proj visits.
        if name == "qkv_proj":
            counter["layer"] = counter["seen"].setdefault(id(w), len(counter["seen"]))
        key = f"L{counter['layer']}.{name}"
        qp = qparams.get(key)
        if qp is None:
            return x @ w.T
        return aser_matmul.aser_qlinear(
            x,
            qp["m"],
            qp["w_packed"],
            qp["w_scales"],
            qp["la"],
            qp["lb"],
            abits=abits,
            block_t=min(64, x.shape[0]),
        )

    return linear_fn


def quantize_params_rtn_int4(cfg: Config, params, rank=16):
    """Build-time helper: naive RTN-int4 + zero low-rank factors for every
    block linear (the AOT demo artifact; the *real* factors come from the
    rust ASER pipeline — this just fixes shapes for the compiled kernel)."""
    qparams = {}
    for l, p in enumerate(params["blocks"]):
        for name, w in [
            ("qkv_proj", p["qkv"]),
            ("out_proj", p["out_proj"]),
            ("fc1", p["fc1"]),
            ("fc2", p["fc2"]),
        ]:
            packed, scales = aser_matmul.quantize_weights_int4(w)
            d_out, d_in = w.shape
            qparams[f"L{l}.{name}"] = {
                "w_packed": packed,
                "w_scales": scales,
                "m": jnp.ones(d_in),
                "la": jnp.zeros((d_out, rank)),
                "lb": jnp.zeros((rank, d_in)),
            }
    return qparams


def fake_quant_forward(cfg: Config, params, tokens, wbits=4, abits=8):
    """W-int/A-int fake-quant forward using the jnp reference (no pallas) —
    the cheap path pretraining uses to sanity-check quantization damage."""

    def linear_fn(name, w, x):
        codes, scales = ref.quant_weight_per_channel(w, wbits)
        return ref.qlinear_ref(x, codes, scales, abits)

    return forward(cfg, params, tokens, linear_fn)


jit_loss_grad = functools.partial(jax.jit, static_argnums=0)(
    lambda cfg, params, batch: jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
)
